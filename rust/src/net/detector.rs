//! Pluggable failure detection (the `detector:` registry axis).
//!
//! * `oracle` (default) — the historical behaviour: the coordinator
//!   learns of a member death within one stabilization period of the
//!   true departure. Bit-exact with the tree before this axis existed.
//! * `swim:PERIOD:SUSPICION:K` — a SWIM-style prober on sim-time
//!   events: every `PERIOD` seconds each online peer pings one random
//!   target; on a failed direct probe it asks `K` random relays to
//!   probe indirectly; if all fail the target becomes *suspect*, and
//!   unless a later round refutes the suspicion (a probe gets through —
//!   the incarnation-bump analogue) the suspect is declared *dead*
//!   after `SUSPICION` seconds. Detection therefore has real latency,
//!   and injected probe loss ([`crate::net::faults::FaultPlane`])
//!   produces a tunable false-positive rate: a live peer can be
//!   declared dead, feeding a truncated lifetime into the estimator
//!   window and a spurious rollback into the coordinator.
//!
//! All randomness comes from a dedicated seeded stream (`0x5317`), so
//! the oracle default consumes nothing and probe-order determinism
//! holds: probers iterate in peer-id order each round.

use super::faults::{FaultPlane, FaultSpec, PartitionSchedule};
use super::overlay::{Overlay, PeerId};
use crate::error::{Error, Result};
use crate::sim::SimTime;
use crate::util::rng::Pcg64;
use std::cmp::Reverse;
use std::collections::BinaryHeap;

/// RNG stream for the SWIM prober.
pub const SWIM_STREAM: u64 = 0x5317;

/// Which failure detector feeds the coordinator and the estimator.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum DetectorSpec {
    /// Perfect detection within one stabilization period (historical).
    Oracle,
    /// SWIM-style probing with the given probe period, suspicion
    /// timeout (both seconds) and indirect probe fan-out.
    Swim { period: f64, suspicion: f64, k_probes: usize },
}

impl Default for DetectorSpec {
    fn default() -> Self {
        DetectorSpec::Oracle
    }
}

impl DetectorSpec {
    /// Canonical registry key: `oracle` or `swim:PERIOD:SUSPICION:K`.
    pub fn key(&self) -> String {
        match self {
            DetectorSpec::Oracle => "oracle".into(),
            DetectorSpec::Swim { period, suspicion, k_probes } => {
                format!("swim:{period}:{suspicion}:{k_probes}")
            }
        }
    }

    /// Parse a detector key.
    pub fn parse(key: &str) -> Result<DetectorSpec> {
        let fields: Vec<&str> = key.split(':').collect();
        let bad = |part: &str| {
            Error::Config(format!("detector key `{key}`: `{part}` is not a number"))
        };
        match fields.as_slice() {
            ["oracle"] => Ok(DetectorSpec::Oracle),
            ["swim", period, suspicion, k] => {
                let spec = DetectorSpec::Swim {
                    period: period.parse().map_err(|_| bad(period))?,
                    suspicion: suspicion.parse().map_err(|_| bad(suspicion))?,
                    k_probes: k.parse().map_err(|_| bad(k))?,
                };
                spec.validated()
            }
            _ => Err(Error::Config(format!(
                "unknown detector key `{key}` — want oracle | swim:PERIOD:SUSPICION:K"
            ))),
        }
    }

    pub fn validated(self) -> Result<DetectorSpec> {
        if let DetectorSpec::Swim { period, suspicion, k_probes } = self {
            if !(period > 0.0) || !(suspicion > 0.0) {
                return Err(Error::Config(format!(
                    "swim period {period} and suspicion {suspicion} must be > 0"
                )));
            }
            if k_probes == 0 {
                return Err(Error::Config("swim k_probes must be >= 1".into()));
            }
        }
        Ok(self)
    }
}

/// A dead declaration produced by [`SwimDetector::expire`].
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Declaration {
    /// Observed lifetime: time since the peer's last (re)join. For a
    /// real death this includes the detection lag; for a false positive
    /// it is a truncated (still-running) session. Both feed the
    /// estimator the way a real deployment's detector would.
    pub lifetime: f64,
    /// The peer was actually still online at declaration time.
    pub false_positive: bool,
}

/// SWIM-style prober state. Driven by the world's `SwimTick` /
/// `SwimExpire` events; owns no event machinery itself.
#[derive(Debug)]
pub struct SwimDetector {
    pub period: f64,
    pub suspicion: f64,
    k_probes: usize,
    rng: Pcg64,
    /// Non-zero while a suspicion timer is pending: the generation the
    /// pending `SwimExpire` event carries. A refutation or rejoin
    /// clears it, invalidating the in-flight expiry.
    suspect_gen: Vec<u64>,
    gen_counter: u64,
    /// Declared dead and not seen rejoining since.
    declared_dead: Vec<bool>,
    /// Last (re)join time, for observed-lifetime accounting.
    joined_at: Vec<f64>,
}

impl SwimDetector {
    pub fn new(spec: DetectorSpec, n_peers: usize, seed: u64) -> Option<SwimDetector> {
        let DetectorSpec::Swim { period, suspicion, k_probes } = spec else {
            return None;
        };
        Some(SwimDetector {
            period,
            suspicion,
            k_probes,
            rng: Pcg64::new(seed, SWIM_STREAM),
            suspect_gen: vec![0; n_peers],
            gen_counter: 0,
            declared_dead: vec![false; n_peers],
            joined_at: vec![0.0; n_peers],
        })
    }

    /// One probe round: every online peer (in id order) probes one
    /// random target; unreachable targets become suspects. Returns the
    /// newly suspected peers with their suspicion generations — the
    /// caller schedules a `SwimExpire { peer, gen }` for each.
    pub fn probe_round(
        &mut self,
        overlay: &Overlay,
        faults: &mut FaultPlane,
        now: f64,
    ) -> Vec<(PeerId, u64)> {
        let n = overlay.len();
        let window = self.period * 0.5;
        let mut suspects = Vec::new();
        for prober in 0..n {
            if !overlay.is_online(prober) {
                continue;
            }
            // Probe target: bounded random draws skipping self and
            // already-declared peers (a fixed draw budget keeps RNG
            // consumption O(n) per round).
            let mut target = None;
            for _ in 0..4 {
                let t = self.rng.next_below(n as u64) as usize;
                if t != prober && !self.declared_dead[t] {
                    target = Some(t);
                    break;
                }
            }
            let Some(t) = target else { continue };
            let reached = (overlay.is_online(t) && !faults.drop_probe(now, prober, t, window))
                || self.indirect_probe(overlay, faults, now, prober, t, window);
            if reached {
                // Alive: refute any pending suspicion (incarnation bump).
                self.suspect_gen[t] = 0;
                continue;
            }
            if self.suspect_gen[t] != 0 {
                continue; // already under suspicion, expiry pending
            }
            self.gen_counter += 1;
            self.suspect_gen[t] = self.gen_counter;
            suspects.push((t, self.gen_counter));
        }
        suspects
    }

    /// `k_probes` indirect probes via random relays; true if any relay
    /// reaches the target and reports back.
    fn indirect_probe(
        &mut self,
        overlay: &Overlay,
        faults: &mut FaultPlane,
        now: f64,
        prober: PeerId,
        target: PeerId,
        window: f64,
    ) -> bool {
        let n = overlay.len();
        for _ in 0..self.k_probes {
            let relay = self.rng.next_below(n as u64) as usize;
            if relay == prober || relay == target || !overlay.is_online(relay) {
                continue;
            }
            let hop1 = !faults.drop_probe(now, prober, relay, window);
            let hop2 = overlay.is_online(target)
                && !faults.drop_probe(now, relay, target, window);
            if hop1 && hop2 {
                return true;
            }
        }
        false
    }

    /// Suspicion timeout fired for `(peer, gen)`. Returns the dead
    /// declaration if the suspicion is still standing (not refuted by a
    /// later probe, not cleared by a rejoin).
    pub fn expire(
        &mut self,
        peer: PeerId,
        gen: u64,
        now: f64,
        overlay: &Overlay,
    ) -> Option<Declaration> {
        if self.suspect_gen.get(peer).copied() != Some(gen) {
            return None;
        }
        self.suspect_gen[peer] = 0;
        let false_positive = overlay.is_online(peer);
        // A false positive clears immediately (the live peer's next
        // incarnation refutes the declaration); a real death stays
        // declared until the peer's rejoin is observed.
        if !false_positive {
            self.declared_dead[peer] = true;
        }
        Some(Declaration { lifetime: (now - self.joined_at[peer]).max(0.0), false_positive })
    }

    /// A peer (re)joined: reset its detector state and lifetime clock.
    pub fn note_join(&mut self, peer: PeerId, now: f64) {
        if peer < self.joined_at.len() {
            self.suspect_gen[peer] = 0;
            self.declared_dead[peer] = false;
            self.joined_at[peer] = now;
        }
    }

    /// Number of peers currently under (unexpired) suspicion.
    pub fn suspected_count(&self) -> usize {
        self.suspect_gen.iter().filter(|&&g| g != 0).count()
    }
}

/// Shard-count-invariant SWIM state for the sharded world
/// ([`crate::coordinator::ShardedWorld`]).
///
/// The probe side ([`Self::probe`]) is a **pure function** of frozen
/// barrier inputs — the overlay snapshot, this struct's declared-dead
/// column (immutable between barriers), the fault spec — plus the
/// *prober's own* RNG stream, so shard threads can evaluate probes
/// concurrently and the outcome cannot depend on how peers are
/// partitioned. Every mutable column (suspicion generations, the expiry
/// queue, declared-dead flags, join clocks) is struct-of-arrays state
/// touched only at barriers, in canonical merged-record order.
#[derive(Debug)]
pub struct BarrierSwim {
    pub period: f64,
    pub suspicion: f64,
    pub k_probes: usize,
    /// Non-zero while a suspicion is pending (the generation its queued
    /// expiry carries); dense column indexed by peer id.
    suspect_gen: Vec<u64>,
    gen_counter: u64,
    /// Declared dead and not seen rejoining since; frozen between
    /// barriers so probe target selection is partition-invariant.
    declared_dead: Vec<bool>,
    /// Last (re)join time, for observed-lifetime accounting.
    joined_at: Vec<f64>,
    /// Pending suspicion expiries as `(expiry µs, peer, gen)`, drained
    /// at barriers interleaved with merged shard records in time order.
    expiries: BinaryHeap<Reverse<(u64, u32, u64)>>,
}

impl BarrierSwim {
    pub fn new(spec: DetectorSpec, n_peers: usize) -> Option<BarrierSwim> {
        let DetectorSpec::Swim { period, suspicion, k_probes } = spec else {
            return None;
        };
        Some(BarrierSwim {
            period,
            suspicion,
            k_probes,
            suspect_gen: vec![0; n_peers],
            gen_counter: 0,
            declared_dead: vec![false; n_peers],
            joined_at: vec![0.0; n_peers],
            expiries: BinaryHeap::new(),
        })
    }

    /// Fixed per-peer detector footprint (the dense columns above,
    /// excluding the transient expiry queue).
    pub fn bytes_per_peer() -> usize {
        std::mem::size_of::<u64>()   // suspect_gen
            + std::mem::size_of::<bool>() // declared_dead
            + std::mem::size_of::<f64>()  // joined_at
    }

    /// One probe by `prober` at `now`, against frozen barrier inputs
    /// and the prober's own RNG stream. Returns the target the prober
    /// failed to reach (directly and via `k_probes` relays), or `None`
    /// when the probe got through or found no target. Draw order per
    /// prober is fixed: up to 4 target draws, a direct-probe fault
    /// check, then per relay one draw plus two hop fault checks.
    pub fn probe(
        &self,
        overlay: &Overlay,
        spec: &FaultSpec,
        partition: Option<&PartitionSchedule>,
        rng: &mut Pcg64,
        prober: PeerId,
        now: f64,
    ) -> Option<PeerId> {
        let n = overlay.len();
        let window = self.period * 0.5;
        let mut target = None;
        for _ in 0..4 {
            let t = rng.next_below(n as u64) as usize;
            if t != prober && !self.declared_dead[t] {
                target = Some(t);
                break;
            }
        }
        let t = target?;
        if overlay.is_online(t) && !spec.drop_probe_with(partition, rng, now, prober, t, window)
        {
            return None;
        }
        for _ in 0..self.k_probes {
            let relay = rng.next_below(n as u64) as usize;
            if relay == prober || relay == t || !overlay.is_online(relay) {
                continue;
            }
            let hop1 = !spec.drop_probe_with(partition, rng, now, prober, relay, window);
            let hop2 = overlay.is_online(t)
                && !spec.drop_probe_with(partition, rng, now, relay, t, window);
            if hop1 && hop2 {
                return None;
            }
        }
        Some(t)
    }

    /// Arm a suspicion for `peer` at barrier application time `now`
    /// (seconds). No-op (returns false) when the peer is already under
    /// suspicion or already declared dead.
    pub fn arm_suspect(&mut self, peer: PeerId, now: f64) -> bool {
        if peer >= self.suspect_gen.len()
            || self.suspect_gen[peer] != 0
            || self.declared_dead[peer]
        {
            return false;
        }
        self.gen_counter += 1;
        self.suspect_gen[peer] = self.gen_counter;
        let expiry = SimTime::from_secs_f64(now + self.suspicion).as_micros();
        self.expiries.push(Reverse((expiry, peer as u32, self.gen_counter)));
        true
    }

    /// Earliest pending suspicion expiry in microseconds, if any.
    pub fn next_expiry_micros(&self) -> Option<u64> {
        self.expiries.peek().map(|Reverse((t, _, _))| *t)
    }

    /// Pop the earliest pending expiry as `(µs, peer, gen)`.
    pub fn pop_expiry(&mut self) -> Option<(u64, u32, u64)> {
        self.expiries.pop().map(|Reverse(e)| e)
    }

    /// A popped expiry fired for `(peer, gen)`. Same semantics as
    /// [`SwimDetector::expire`]: the declaration stands unless a rejoin
    /// cleared the generation in the meantime; a live peer is a false
    /// positive and clears immediately.
    pub fn expire(
        &mut self,
        peer: PeerId,
        gen: u64,
        now: f64,
        online: bool,
    ) -> Option<Declaration> {
        if self.suspect_gen.get(peer).copied() != Some(gen) {
            return None;
        }
        self.suspect_gen[peer] = 0;
        if !online {
            self.declared_dead[peer] = true;
        }
        Some(Declaration {
            lifetime: (now - self.joined_at[peer]).max(0.0),
            false_positive: online,
        })
    }

    /// A peer (re)joined: reset its detector state and lifetime clock.
    pub fn note_join(&mut self, peer: PeerId, now: f64) {
        if peer < self.joined_at.len() {
            self.suspect_gen[peer] = 0;
            self.declared_dead[peer] = false;
            self.joined_at[peer] = now;
        }
    }

    /// Number of peers currently under (unexpired) suspicion.
    pub fn suspected_count(&self) -> usize {
        self.suspect_gen.iter().filter(|&&g| g != 0).count()
    }

    /// Number of peers currently declared dead.
    pub fn declared_count(&self) -> usize {
        self.declared_dead.iter().filter(|&&d| d).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::net::faults::FaultSpec;

    fn mk(n: usize) -> (Overlay, FaultPlane, SwimDetector) {
        let mut rng = Pcg64::new(5, 1);
        let overlay = Overlay::new(n, &mut rng);
        let faults = FaultPlane::new(FaultSpec::default(), n, 5);
        let swim = SwimDetector::new(
            DetectorSpec::Swim { period: 10.0, suspicion: 30.0, k_probes: 3 },
            n,
            5,
        )
        .unwrap();
        (overlay, faults, swim)
    }

    #[test]
    fn key_round_trips() {
        for key in ["oracle", "swim:10:30:3", "swim:5:12.5:2"] {
            let spec = DetectorSpec::parse(key).unwrap();
            assert_eq!(spec.key(), key);
        }
        for bad in ["swim", "swim:10:30", "swim:0:30:3", "swim:10:30:0", "gossip", ""] {
            assert!(DetectorSpec::parse(bad).is_err(), "`{bad}` must not parse");
        }
    }

    #[test]
    fn oracle_builds_no_detector() {
        assert!(SwimDetector::new(DetectorSpec::Oracle, 10, 1).is_none());
    }

    #[test]
    fn dead_peer_is_suspected_then_declared() {
        let (mut overlay, mut faults, mut swim) = mk(64);
        overlay.depart(7, 100.0);
        // A 64-peer population probing once per round finds the corpse
        // within a few rounds with overwhelming probability.
        let mut suspected = Vec::new();
        for round in 0..20 {
            let t = 100.0 + 10.0 * round as f64;
            suspected = swim.probe_round(&overlay, &mut faults, t);
            if suspected.iter().any(|&(p, _)| p == 7) {
                break;
            }
        }
        let &(_, gen) = suspected.iter().find(|&&(p, _)| p == 7).expect("7 never suspected");
        let decl = swim.expire(7, gen, 400.0, &overlay).expect("suspicion must stand");
        assert!(!decl.false_positive);
        assert!(decl.lifetime > 0.0);
        // Double-expiry is a no-op.
        assert!(swim.expire(7, gen, 401.0, &overlay).is_none());
    }

    #[test]
    fn no_false_positives_without_faults() {
        let (overlay, mut faults, mut swim) = mk(64);
        for round in 0..50 {
            let s = swim.probe_round(&overlay, &mut faults, 10.0 * round as f64);
            assert!(s.is_empty(), "all-online fault-free round suspected {s:?}");
        }
    }

    #[test]
    fn rejoin_clears_suspicion_and_resets_lifetime() {
        let (mut overlay, mut faults, mut swim) = mk(64);
        overlay.depart(3, 50.0);
        let mut gen = 0;
        for round in 0..20 {
            let s = swim.probe_round(&overlay, &mut faults, 50.0 + 10.0 * round as f64);
            if let Some(&(_, g)) = s.iter().find(|&&(p, _)| p == 3) {
                gen = g;
                break;
            }
        }
        assert!(gen != 0, "3 never suspected");
        overlay.join(3, 200.0);
        swim.note_join(3, 200.0);
        assert!(
            swim.expire(3, gen, 230.0, &overlay).is_none(),
            "rejoin must invalidate the in-flight expiry"
        );
    }

    #[test]
    fn barrier_swim_probe_is_prober_order_invariant() {
        let n = 64;
        let mut rng = Pcg64::new(5, 1);
        let mut overlay = Overlay::new(n, &mut rng);
        overlay.depart(7, 100.0);
        let spec = FaultSpec::parse("loss:0.2").unwrap();
        let swim = BarrierSwim::new(
            DetectorSpec::Swim { period: 10.0, suspicion: 30.0, k_probes: 3 },
            n,
        )
        .unwrap();
        let run = |order: &[usize]| {
            let mut out = vec![None; n];
            for &p in order {
                let mut prng = Pcg64::new(5, 0x9000 + p as u64);
                out[p] = swim.probe(&overlay, &spec, None, &mut prng, p, 100.0);
            }
            out
        };
        let forward: Vec<usize> = (0..n).collect();
        let reverse: Vec<usize> = (0..n).rev().collect();
        assert_eq!(
            run(&forward),
            run(&reverse),
            "per-prober streams must make probe outcomes independent of eval order"
        );
    }

    #[test]
    fn barrier_swim_suspect_expire_and_rejoin() {
        let mut swim = BarrierSwim::new(
            DetectorSpec::Swim { period: 10.0, suspicion: 30.0, k_probes: 3 },
            16,
        )
        .unwrap();
        assert!(swim.arm_suspect(3, 100.0));
        assert!(!swim.arm_suspect(3, 101.0), "double-arm must be a no-op");
        assert_eq!(swim.suspected_count(), 1);
        let (t, peer, gen) = swim.pop_expiry().expect("expiry queued");
        assert_eq!((t, peer), (SimTime::from_secs_f64(130.0).as_micros(), 3));
        // Dead at expiry: declared, lifetime runs from joined_at (0.0).
        let d = swim.expire(peer as usize, gen, 130.0, false).expect("stands");
        assert!(!d.false_positive);
        assert!((d.lifetime - 130.0).abs() < 1e-9);
        assert_eq!(swim.declared_count(), 1);
        // Rejoin clears the declaration and invalidates stale expiries.
        swim.note_join(3, 200.0);
        assert_eq!(swim.declared_count(), 0);
        assert!(swim.arm_suspect(3, 210.0));
        let (_, _, gen2) = swim.pop_expiry().unwrap();
        swim.note_join(3, 220.0);
        assert!(swim.expire(3, gen2, 240.0, true).is_none(), "rejoin refutes");
        // False positive: declaration emitted but peer stays undeclared.
        assert!(swim.arm_suspect(5, 300.0));
        let (_, _, g5) = swim.pop_expiry().unwrap();
        let fp = swim.expire(5, g5, 330.0, true).unwrap();
        assert!(fp.false_positive);
        assert_eq!(swim.declared_count(), 0);
    }

    #[test]
    fn lossy_probes_produce_false_positives_eventually() {
        let n = 64;
        let mut rng = Pcg64::new(9, 1);
        let overlay = Overlay::new(n, &mut rng);
        // Extreme loss so the FP path triggers quickly and determinism
        // of the test does not hinge on a rare event.
        let mut faults = FaultPlane::new(FaultSpec::parse("loss:0.9").unwrap(), n, 9);
        let mut swim = SwimDetector::new(
            DetectorSpec::Swim { period: 10.0, suspicion: 30.0, k_probes: 2 },
            n,
            9,
        )
        .unwrap();
        let mut fp = 0;
        for round in 0..40 {
            let t = 10.0 * round as f64;
            for (p, gen) in swim.probe_round(&overlay, &mut faults, t) {
                if let Some(d) = swim.expire(p, gen, t + 30.0, &overlay) {
                    assert!(d.false_positive, "everyone is online");
                    fp += 1;
                }
            }
        }
        assert!(fp > 0, "90% probe loss must yield false positives");
    }
}
