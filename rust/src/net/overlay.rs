//! Chord-style ring overlay with successor lists and finger tables.
//!
//! Peers own random 64-bit ids on a ring. Each live peer keeps
//! `SUCCESSORS` immediate successors (its "neighbours" — the peers whose
//! failures it can observe during stabilization) and `log2(n)`-ish fingers
//! for greedy routing. The overlay tracks join/leave and exposes the
//! neighbour sets the failure detector watches.

use crate::util::rng::Pcg64;
use std::collections::BTreeMap;

/// Index into the overlay's peer table (stable across sessions).
pub type PeerId = usize;

/// Number of successor links each peer maintains (its neighbour set).
pub const SUCCESSORS: usize = 4;

/// Per-peer state.
#[derive(Debug, Clone)]
pub struct PeerState {
    /// Position on the 64-bit ring.
    pub ring_id: u64,
    /// Online?
    pub online: bool,
    /// Start of the current session (secs), if online.
    pub session_start: f64,
    /// Sessions completed so far (diagnostics).
    pub sessions: u64,
}

/// The overlay: peer table plus a ring index of the online peers.
#[derive(Debug)]
pub struct Overlay {
    peers: Vec<PeerState>,
    /// ring_id -> peer, online peers only.
    ring: BTreeMap<u64, PeerId>,
}

impl Overlay {
    /// Create an overlay of `n` peers, all initially online with random
    /// ring positions, sessions starting at time 0.
    pub fn new(n: usize, rng: &mut Pcg64) -> Overlay {
        let mut peers = Vec::with_capacity(n);
        let mut ring = BTreeMap::new();
        for i in 0..n {
            // Distinct ring ids (collisions are ~impossible but be strict).
            let mut rid = rng.next_u64();
            while ring.contains_key(&rid) {
                rid = rng.next_u64();
            }
            ring.insert(rid, i);
            peers.push(PeerState {
                ring_id: rid,
                online: true,
                session_start: 0.0,
                sessions: 1,
            });
        }
        Overlay { peers, ring }
    }

    pub fn len(&self) -> usize {
        self.peers.len()
    }

    pub fn is_empty(&self) -> bool {
        self.peers.is_empty()
    }

    pub fn online_count(&self) -> usize {
        self.ring.len()
    }

    pub fn peer(&self, p: PeerId) -> &PeerState {
        &self.peers[p]
    }

    pub fn is_online(&self, p: PeerId) -> bool {
        self.peers[p].online
    }

    /// Mark `p` offline (session end). Returns the session length.
    pub fn depart(&mut self, p: PeerId, now: f64) -> f64 {
        let st = &mut self.peers[p];
        debug_assert!(st.online, "departing an offline peer");
        st.online = false;
        self.ring.remove(&st.ring_id);
        now - st.session_start
    }

    /// Bring `p` back online at `now` with a fresh session.
    pub fn join(&mut self, p: PeerId, now: f64) {
        let st = &mut self.peers[p];
        debug_assert!(!st.online, "joining an online peer");
        st.online = true;
        st.session_start = now;
        st.sessions += 1;
        self.ring.insert(st.ring_id, p);
    }

    /// The `k` online successors of `p` on the ring (p's neighbour set).
    pub fn successors(&self, p: PeerId, k: usize) -> Vec<PeerId> {
        let start = self.peers[p].ring_id;
        let mut out = Vec::with_capacity(k);
        for (_, &q) in self.ring.range((start + 1)..).chain(self.ring.range(..=start)) {
            if q == p {
                continue;
            }
            out.push(q);
            if out.len() == k {
                break;
            }
        }
        out
    }

    /// Neighbour set used by the failure detector: successor list.
    pub fn neighbours(&self, p: PeerId) -> Vec<PeerId> {
        self.successors(p, SUCCESSORS)
    }

    /// Allocation-free iterator over the first `SUCCESSORS` online
    /// successors of `p` (hot-path twin of [`Overlay::neighbours`]).
    pub fn successors_iter(&self, p: PeerId) -> impl Iterator<Item = PeerId> + '_ {
        let start = self.peers[p].ring_id;
        self.ring
            .range((start + 1)..)
            .chain(self.ring.range(..=start))
            .map(|(_, &q)| q)
            .filter(move |&q| q != p)
            .take(SUCCESSORS)
    }

    /// The online peer owning ring key `key` (first peer clockwise).
    pub fn owner_of(&self, key: u64) -> Option<PeerId> {
        self.ring
            .range(key..)
            .next()
            .or_else(|| self.ring.iter().next())
            .map(|(_, &p)| p)
    }

    /// Sample `k` distinct online peers (for job placement).
    pub fn sample_online(&self, k: usize, rng: &mut Pcg64) -> Option<Vec<PeerId>> {
        let online: Vec<PeerId> = self.online_ids().collect();
        if online.len() < k {
            return None;
        }
        let idx = rng.sample_indices(online.len(), k);
        Some(idx.into_iter().map(|i| online[i]).collect())
    }

    pub fn online_ids(&self) -> impl Iterator<Item = PeerId> + '_ {
        self.ring.values().copied()
    }

    /// Finger targets for routing: the owners of ring_id + 2^i.
    pub fn fingers(&self, p: PeerId) -> Vec<PeerId> {
        let base = self.peers[p].ring_id;
        let mut out = Vec::with_capacity(64);
        for i in 0..64 {
            let key = base.wrapping_add(1u64 << i);
            if let Some(q) = self.owner_of(key) {
                if q != p && out.last() != Some(&q) {
                    out.push(q);
                }
            }
        }
        out.dedup();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(n: usize) -> (Overlay, Pcg64) {
        let mut rng = Pcg64::new(42, 0);
        let o = Overlay::new(n, &mut rng);
        (o, rng)
    }

    #[test]
    fn all_online_initially() {
        let (o, _) = mk(100);
        assert_eq!(o.online_count(), 100);
        assert_eq!(o.len(), 100);
    }

    #[test]
    fn depart_join_cycle() {
        let (mut o, _) = mk(10);
        let len = o.depart(3, 1234.5);
        assert!((len - 1234.5).abs() < 1e-9);
        assert!(!o.is_online(3));
        assert_eq!(o.online_count(), 9);
        o.join(3, 2000.0);
        assert!(o.is_online(3));
        assert_eq!(o.peer(3).sessions, 2);
        assert_eq!(o.peer(3).session_start, 2000.0);
    }

    #[test]
    fn successors_wrap_and_skip_offline() {
        let (mut o, _) = mk(6);
        // Take one peer offline; successor sets must never contain it.
        o.depart(2, 1.0);
        for p in 0..6 {
            if p == 2 {
                continue;
            }
            let succ = o.successors(p, 3);
            assert_eq!(succ.len(), 3);
            assert!(!succ.contains(&2));
            assert!(!succ.contains(&p));
        }
    }

    #[test]
    fn owner_of_covers_whole_ring() {
        let (o, mut rng) = mk(50);
        for _ in 0..1000 {
            let key = rng.next_u64();
            let owner = o.owner_of(key).unwrap();
            assert!(o.is_online(owner));
        }
    }

    #[test]
    fn owner_is_clockwise_successor() {
        let (o, _) = mk(20);
        for key in [0u64, 1, u64::MAX / 2, u64::MAX - 1] {
            let owner = o.owner_of(key).unwrap();
            let oid = o.peer(owner).ring_id;
            // No online peer sits strictly between key and owner (clockwise).
            for p in o.online_ids() {
                let rid = o.peer(p).ring_id;
                if rid >= key {
                    assert!(oid >= key && oid <= rid || oid == rid, "closer peer exists");
                }
            }
        }
    }

    #[test]
    fn sample_online_distinct_and_online() {
        let (mut o, mut rng) = mk(30);
        for p in 0..10 {
            o.depart(p, 1.0);
        }
        let s = o.sample_online(16, &mut rng).unwrap();
        assert_eq!(s.len(), 16);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 16);
        assert!(s.iter().all(|&p| o.is_online(p)));
        assert!(o.sample_online(25, &mut rng).is_none());
    }

    #[test]
    fn fingers_nonempty_and_online() {
        let (o, _) = mk(64);
        let f = o.fingers(0);
        assert!(f.len() >= 4, "fingers {len}", len = f.len());
        assert!(f.iter().all(|&q| o.is_online(q)));
    }
}
