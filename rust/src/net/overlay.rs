//! Chord-style ring overlay with successor lists and finger tables.
//!
//! Peers own random 64-bit ids on a ring. Each live peer keeps
//! `SUCCESSORS` immediate successors (its "neighbours" — the peers whose
//! failures it can observe during stabilization) and `log2(n)`-ish fingers
//! for greedy routing. The overlay tracks join/leave and exposes the
//! neighbour sets the failure detector watches.
//!
//! # Hot-path data structures
//!
//! The overlay is on the per-event hot path of the full-stack world
//! (every stabilization tick walks a successor list; every job placement
//! samples members), so it keeps two indices over the online population:
//!
//! * a **bucketed ring index** ([`RingIndex`]) — online peers sorted by
//!   ring id, sharded into power-of-two buckets by the id's top bits.
//!   Ring ids are uniform, so buckets hold O(1) entries: successor scans,
//!   `owner_of`, joins and departs are all O(1) expected with contiguous
//!   memory, replacing the pointer-chasing `BTreeMap` the seed used;
//! * a **dense online set** — a swap-remove vector plus a per-peer index
//!   map, giving O(1) membership updates and O(k) uniform sampling
//!   (`sample_online`, `sample_online_excluding`) with no "collect every
//!   online id" scans anywhere.
//!
//! The overlay also keeps a **churn journal** ([`ChurnEvent`]): every
//! `depart`/`join` appends one sequence-numbered event. Consumers that
//! maintain state proportional to the membership (the data-plane's
//! inverted holder index) hold a cursor and replay only the events since
//! their last sync — O(churn) instead of O(stored state) per maintenance
//! period. The journal is compacted by its owner via
//! [`Overlay::compact_churn`] once the (single) consumer has caught up.

use crate::util::rng::Pcg64;
use std::sync::atomic::{AtomicU64, Ordering};

/// Index into the overlay's peer table (stable across sessions).
pub type PeerId = usize;

/// Number of successor links each peer maintains (its neighbour set).
pub const SUCCESSORS: usize = 4;

/// Sentinel for "not in the dense online vector".
const OFFLINE: usize = usize::MAX;

/// Distinguishes overlay instances so a journal consumer can detect that
/// it was handed a *different* overlay (not just a later state of the one
/// it synced against). Monotonic, never 0 — consumers can use 0 as
/// "never attached". Deliberately process-global: the token gates only
/// which code path answers a query, never the answer itself, so it does
/// not perturb determinism.
static NEXT_OVERLAY_TOKEN: AtomicU64 = AtomicU64::new(1);

/// One churn-journal entry: `peer` went online/offline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ChurnEvent {
    pub peer: u32,
    pub online: bool,
}

/// Per-peer state snapshot, assembled on demand from the overlay's
/// struct-of-arrays columns (see [`Overlay::peer`]). Cheap to copy; the
/// authoritative storage is the dense per-field `Vec`s.
#[derive(Debug, Clone, Copy)]
pub struct PeerState {
    /// Position on the 64-bit ring.
    pub ring_id: u64,
    /// Online?
    pub online: bool,
    /// Start of the current session (secs), if online.
    pub session_start: f64,
    /// Sessions completed so far (diagnostics).
    pub sessions: u64,
}

/// Sorted ring membership sharded by the top bits of the ring id.
///
/// `buckets[rid >> shift]` holds `(ring_id, peer)` pairs sorted ascending;
/// concatenating the buckets in order yields the whole ring sorted. With
/// uniform ids and load factor ~4, every operation touches one or two
/// small contiguous vectors.
#[derive(Debug)]
struct RingIndex {
    shift: u32,
    buckets: Vec<Vec<(u64, u32)>>,
    len: usize,
}

impl RingIndex {
    fn with_capacity(n: usize) -> RingIndex {
        // Target ~4 entries per bucket at full population, min 16 buckets.
        let buckets = (n / 4).next_power_of_two().max(16);
        RingIndex {
            shift: 64 - buckets.trailing_zeros(),
            buckets: (0..buckets).map(|_| Vec::new()).collect(),
            len: 0,
        }
    }

    fn len(&self) -> usize {
        self.len
    }

    fn bucket_of(&self, rid: u64) -> usize {
        (rid >> self.shift) as usize
    }

    fn contains(&self, rid: u64) -> bool {
        let b = self.bucket_of(rid);
        self.buckets[b].binary_search_by_key(&rid, |&(r, _)| r).is_ok()
    }

    fn insert(&mut self, rid: u64, p: PeerId) {
        let b = self.bucket_of(rid);
        let bucket = &mut self.buckets[b];
        let pos = bucket.partition_point(|&(r, _)| r < rid);
        bucket.insert(pos, (rid, p as u32));
        self.len += 1;
    }

    fn remove(&mut self, rid: u64) {
        let b = self.bucket_of(rid);
        let bucket = &mut self.buckets[b];
        let pos = bucket.partition_point(|&(r, _)| r < rid);
        debug_assert!(pos < bucket.len() && bucket[pos].0 == rid, "rid not in ring");
        bucket.remove(pos);
        self.len -= 1;
    }

    /// Circular iterator over peers in ascending ring order, starting at
    /// the first entry with `ring_id >= key` and wrapping once around.
    fn iter_from(&self, key: u64) -> RingIter<'_> {
        let start_bucket = self.bucket_of(key);
        let start_pos = self.buckets[start_bucket].partition_point(|&(r, _)| r < key);
        RingIter {
            buckets: &self.buckets,
            start_bucket,
            start_pos,
            bucket: start_bucket,
            pos: start_pos,
            wrapped: false,
        }
    }

    /// All peers in ascending ring order.
    fn iter(&self) -> impl Iterator<Item = PeerId> + '_ {
        self.buckets.iter().flat_map(|b| b.iter().map(|&(_, p)| p as PeerId))
    }
}

/// See [`RingIndex::iter_from`]. Yields every online peer exactly once.
struct RingIter<'a> {
    buckets: &'a [Vec<(u64, u32)>],
    start_bucket: usize,
    start_pos: usize,
    bucket: usize,
    pos: usize,
    wrapped: bool,
}

impl<'a> Iterator for RingIter<'a> {
    type Item = PeerId;

    fn next(&mut self) -> Option<PeerId> {
        loop {
            let closing = self.wrapped && self.bucket == self.start_bucket;
            let bucket = &self.buckets[self.bucket];
            let limit = if closing { self.start_pos } else { bucket.len() };
            if self.pos < limit {
                let (_, p) = bucket[self.pos];
                self.pos += 1;
                return Some(p as PeerId);
            }
            if closing {
                return None;
            }
            self.bucket += 1;
            self.pos = 0;
            if self.bucket == self.buckets.len() {
                self.bucket = 0;
                self.wrapped = true;
            }
        }
    }
}

/// The overlay: peer table plus the two online indices (sorted ring,
/// dense sampling set).
///
/// The peer table is stored struct-of-arrays: one dense `Vec` column per
/// field, indexed by peer id. At 1M peers that is four cache-friendly
/// columns (~25 B/peer of authoritative state) instead of a million
/// scattered structs, and sharded worlds can hand each shard a disjoint
/// range of the columns.
#[derive(Debug)]
pub struct Overlay {
    /// Column: ring position of each peer (fixed at construction).
    ring_ids: Vec<u64>,
    /// Column: online flag.
    online_flags: Vec<bool>,
    /// Column: start of the current session (secs), if online.
    session_starts: Vec<f64>,
    /// Column: sessions completed so far (diagnostics).
    session_counts: Vec<u64>,
    /// Online peers sorted by ring id.
    ring: RingIndex,
    /// Online peers in swap-remove order (uniform O(1) sampling).
    online: Vec<PeerId>,
    /// peer -> its index in `online`, [`OFFLINE`] when offline.
    online_pos: Vec<usize>,
    /// Instance token (see [`Overlay::token`]).
    token: u64,
    /// Churn journal: events `churn_base..churn_base + churn_log.len()`.
    /// Initial membership is not journalled — consumers attach to the
    /// overlay's *current* state and replay deltas from there.
    churn_log: Vec<ChurnEvent>,
    /// Absolute sequence number of `churn_log[0]`.
    churn_base: u64,
}

impl Overlay {
    /// Create an overlay of `n` peers, all initially online with random
    /// ring positions, sessions starting at time 0.
    pub fn new(n: usize, rng: &mut Pcg64) -> Overlay {
        let mut ring_ids = Vec::with_capacity(n);
        let mut ring = RingIndex::with_capacity(n);
        for i in 0..n {
            // Distinct ring ids (collisions are ~impossible but be strict).
            let mut rid = rng.next_u64();
            while ring.contains(rid) {
                rid = rng.next_u64();
            }
            ring.insert(rid, i);
            ring_ids.push(rid);
        }
        Overlay {
            ring_ids,
            online_flags: vec![true; n],
            session_starts: vec![0.0; n],
            session_counts: vec![1; n],
            ring,
            online: (0..n).collect(),
            online_pos: (0..n).collect(),
            token: NEXT_OVERLAY_TOKEN.fetch_add(1, Ordering::Relaxed),
            churn_log: Vec::new(),
            churn_base: 0,
        }
    }

    /// Instance token for journal consumers (never 0).
    pub fn token(&self) -> u64 {
        self.token
    }

    /// Sequence number the *next* churn event will get; a consumer whose
    /// cursor equals this value has replayed every membership change.
    pub fn churn_seq(&self) -> u64 {
        self.churn_base + self.churn_log.len() as u64
    }

    /// Compaction horizon: the oldest event sequence still in the
    /// journal. A consumer whose cursor predates this cannot replay
    /// (another consumer advanced the compaction point past it) and must
    /// rebuild from the overlay's current state instead.
    pub fn churn_horizon(&self) -> u64 {
        self.churn_base
    }

    /// Journal entries from absolute sequence `since` onward. `since`
    /// must not predate the compaction horizon — a consumer can never be
    /// behind the compaction point it advanced itself.
    pub fn churn_events_since(&self, since: u64) -> &[ChurnEvent] {
        debug_assert!(
            since >= self.churn_base,
            "churn cursor {since} predates compaction horizon {}",
            self.churn_base
        );
        let start = (since.saturating_sub(self.churn_base) as usize).min(self.churn_log.len());
        &self.churn_log[start..]
    }

    /// Drop journal entries below `upto` (the consumer's cursor). Called
    /// by the overlay's owner once the journal consumer has synced.
    pub fn compact_churn(&mut self, upto: u64) {
        let n = (upto.saturating_sub(self.churn_base) as usize).min(self.churn_log.len());
        if n > 0 {
            self.churn_log.drain(..n);
            self.churn_base += n as u64;
        }
    }

    pub fn len(&self) -> usize {
        self.ring_ids.len()
    }

    pub fn is_empty(&self) -> bool {
        self.ring_ids.is_empty()
    }

    pub fn online_count(&self) -> usize {
        debug_assert_eq!(self.ring.len(), self.online.len());
        self.online.len()
    }

    /// Snapshot of peer `p`, gathered from the columns (by value — the
    /// columns are the authoritative storage).
    pub fn peer(&self, p: PeerId) -> PeerState {
        PeerState {
            ring_id: self.ring_ids[p],
            online: self.online_flags[p],
            session_start: self.session_starts[p],
            sessions: self.session_counts[p],
        }
    }

    pub fn is_online(&self, p: PeerId) -> bool {
        self.online_flags[p]
    }

    /// Ring position of peer `p` (column read; hot-path twin of
    /// `peer(p).ring_id`).
    pub fn ring_id(&self, p: PeerId) -> u64 {
        self.ring_ids[p]
    }

    /// Start of `p`'s current session (column read).
    pub fn session_start(&self, p: PeerId) -> f64 {
        self.session_starts[p]
    }

    /// Authoritative per-peer bytes of the overlay's dense state: the
    /// four SoA columns plus the two online indices and the ring
    /// index's `(u64, u32)` entries. Reported by the 1M-peer perf tier
    /// so layout regressions show up as a number, not an OOM.
    pub fn bytes_per_peer() -> usize {
        use std::mem::size_of;
        size_of::<u64>()            // ring_ids
            + size_of::<bool>()     // online_flags
            + size_of::<f64>()      // session_starts
            + size_of::<u64>()      // session_counts
            + size_of::<usize>()    // online
            + size_of::<usize>()    // online_pos
            + size_of::<(u64, u32)>() // ring index entry
    }

    /// Mark `p` offline (session end). Returns the session length.
    pub fn depart(&mut self, p: PeerId, now: f64) -> f64 {
        debug_assert!(self.online_flags[p], "departing an offline peer");
        self.online_flags[p] = false;
        self.ring.remove(self.ring_ids[p]);
        let i = self.online_pos[p];
        debug_assert!(i != OFFLINE && self.online[i] == p);
        self.online.swap_remove(i);
        if let Some(&moved) = self.online.get(i) {
            self.online_pos[moved] = i;
        }
        self.online_pos[p] = OFFLINE;
        self.churn_log.push(ChurnEvent { peer: p as u32, online: false });
        now - self.session_starts[p]
    }

    /// Bring `p` back online at `now` with a fresh session.
    pub fn join(&mut self, p: PeerId, now: f64) {
        debug_assert!(!self.online_flags[p], "joining an online peer");
        self.online_flags[p] = true;
        self.session_starts[p] = now;
        self.session_counts[p] += 1;
        self.ring.insert(self.ring_ids[p], p);
        self.online_pos[p] = self.online.len();
        self.online.push(p);
        self.churn_log.push(ChurnEvent { peer: p as u32, online: true });
    }

    /// The `k` online successors of `p` on the ring (p's neighbour set).
    pub fn successors(&self, p: PeerId, k: usize) -> Vec<PeerId> {
        self.successors_from(p, k).collect()
    }

    /// Neighbour set used by the failure detector: successor list.
    pub fn neighbours(&self, p: PeerId) -> Vec<PeerId> {
        self.successors(p, SUCCESSORS)
    }

    /// Allocation-free iterator over the first `SUCCESSORS` online
    /// successors of `p` (hot-path twin of [`Overlay::neighbours`]).
    pub fn successors_iter(&self, p: PeerId) -> impl Iterator<Item = PeerId> + '_ {
        self.successors_from(p, SUCCESSORS)
    }

    /// Allocation-free iterator over the first `k` online successors of
    /// `p` (generic-arity twin of [`Overlay::successors`], used by the
    /// data-plane's candidate selection).
    pub fn successors_from(&self, p: PeerId, k: usize) -> impl Iterator<Item = PeerId> + '_ {
        let start = self.ring_ids[p];
        self.ring
            .iter_from(start.wrapping_add(1))
            .filter(move |&q| q != p)
            .take(k)
    }

    /// The online peer owning ring key `key` (first peer clockwise).
    pub fn owner_of(&self, key: u64) -> Option<PeerId> {
        self.ring.iter_from(key).next()
    }

    /// Sample `k` distinct online peers (for job placement). O(k) expected
    /// for sparse draws; one O(n) scratch pass when `k` approaches the
    /// online count.
    pub fn sample_online(&self, k: usize, rng: &mut Pcg64) -> Option<Vec<PeerId>> {
        let n = self.online.len();
        if n < k {
            return None;
        }
        if k * 2 >= n {
            // Dense draw: partial Fisher–Yates over a scratch copy.
            let mut pool = self.online.clone();
            for i in 0..k {
                let j = i + rng.next_below((n - i) as u64) as usize;
                pool.swap(i, j);
            }
            pool.truncate(k);
            Some(pool)
        } else {
            // Sparse draw: rejection against the (small) chosen set.
            let mut out = Vec::with_capacity(k);
            while out.len() < k {
                let p = self.online[rng.next_below(n as u64) as usize];
                if !out.contains(&p) {
                    out.push(p);
                }
            }
            Some(out)
        }
    }

    /// One uniformly-drawn online peer not in `exclude`, or `None` when
    /// every online peer is excluded. O(|exclude|) plus O(1) expected
    /// draws — the hot-path replacement for "collect all online ids and
    /// index into them".
    pub fn sample_online_excluding(
        &self,
        exclude: &[PeerId],
        rng: &mut Pcg64,
    ) -> Option<PeerId> {
        let n = self.online.len();
        let excluded_online = exclude.iter().filter(|&&p| self.is_online(p)).count();
        if n == 0 || n <= excluded_online {
            return None;
        }
        loop {
            let p = self.online[rng.next_below(n as u64) as usize];
            if !exclude.contains(&p) {
                return Some(p);
            }
        }
    }

    /// Online peers in ascending ring order.
    pub fn online_ids(&self) -> impl Iterator<Item = PeerId> + '_ {
        self.ring.iter()
    }

    /// Finger targets for routing: the owners of ring_id + 2^i.
    pub fn fingers(&self, p: PeerId) -> Vec<PeerId> {
        let base = self.ring_ids[p];
        let mut out = Vec::with_capacity(64);
        for i in 0..64 {
            let key = base.wrapping_add(1u64 << i);
            if let Some(q) = self.owner_of(key) {
                if q != p && out.last() != Some(&q) {
                    out.push(q);
                }
            }
        }
        out.dedup();
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(n: usize) -> (Overlay, Pcg64) {
        let mut rng = Pcg64::new(42, 0);
        let o = Overlay::new(n, &mut rng);
        (o, rng)
    }

    #[test]
    fn all_online_initially() {
        let (o, _) = mk(100);
        assert_eq!(o.online_count(), 100);
        assert_eq!(o.len(), 100);
    }

    #[test]
    fn depart_join_cycle() {
        let (mut o, _) = mk(10);
        let len = o.depart(3, 1234.5);
        assert!((len - 1234.5).abs() < 1e-9);
        assert!(!o.is_online(3));
        assert_eq!(o.online_count(), 9);
        o.join(3, 2000.0);
        assert!(o.is_online(3));
        assert_eq!(o.peer(3).sessions, 2);
        assert_eq!(o.peer(3).session_start, 2000.0);
    }

    #[test]
    fn successors_wrap_and_skip_offline() {
        let (mut o, _) = mk(6);
        // Take one peer offline; successor sets must never contain it.
        o.depart(2, 1.0);
        for p in 0..6 {
            if p == 2 {
                continue;
            }
            let succ = o.successors(p, 3);
            assert_eq!(succ.len(), 3);
            assert!(!succ.contains(&2));
            assert!(!succ.contains(&p));
        }
    }

    #[test]
    fn successors_are_sorted_clockwise_from_p() {
        let (o, _) = mk(40);
        for p in 0..40 {
            let start = o.peer(p).ring_id;
            let succ = o.successors(p, 8);
            assert_eq!(succ.len(), 8);
            // Clockwise distance from p must be strictly increasing.
            let dist =
                |q: PeerId| o.peer(q).ring_id.wrapping_sub(start.wrapping_add(1));
            for w in succ.windows(2) {
                assert!(dist(w[0]) < dist(w[1]), "successors out of ring order");
            }
        }
    }

    #[test]
    fn owner_of_covers_whole_ring() {
        let (o, mut rng) = mk(50);
        for _ in 0..1000 {
            let key = rng.next_u64();
            let owner = o.owner_of(key).unwrap();
            assert!(o.is_online(owner));
        }
    }

    #[test]
    fn owner_is_clockwise_successor() {
        let (o, _) = mk(20);
        for key in [0u64, 1, u64::MAX / 2, u64::MAX - 1] {
            let owner = o.owner_of(key).unwrap();
            let oid = o.peer(owner).ring_id;
            // No online peer sits strictly between key and owner (clockwise).
            for p in o.online_ids() {
                let rid = o.peer(p).ring_id;
                if rid >= key {
                    assert!(oid >= key && oid <= rid || oid == rid, "closer peer exists");
                }
            }
        }
    }

    #[test]
    fn online_ids_are_ring_sorted() {
        let (mut o, _) = mk(64);
        for p in [3, 17, 40] {
            o.depart(p, 1.0);
        }
        let ids: Vec<PeerId> = o.online_ids().collect();
        assert_eq!(ids.len(), 61);
        for w in ids.windows(2) {
            assert!(o.peer(w[0]).ring_id < o.peer(w[1]).ring_id);
        }
    }

    #[test]
    fn sample_online_distinct_and_online() {
        let (mut o, mut rng) = mk(30);
        for p in 0..10 {
            o.depart(p, 1.0);
        }
        let s = o.sample_online(16, &mut rng).unwrap();
        assert_eq!(s.len(), 16);
        let mut d = s.clone();
        d.sort_unstable();
        d.dedup();
        assert_eq!(d.len(), 16);
        assert!(s.iter().all(|&p| o.is_online(p)));
        assert!(o.sample_online(25, &mut rng).is_none());
        // Sparse branch: k well under half the online population.
        let sparse = o.sample_online(3, &mut rng).unwrap();
        assert_eq!(sparse.len(), 3);
        assert!(sparse.iter().all(|&p| o.is_online(p)));
    }

    #[test]
    fn sample_online_excluding_avoids_exclusions() {
        let (mut o, mut rng) = mk(12);
        let exclude: Vec<PeerId> = vec![0, 1, 2, 3];
        for _ in 0..200 {
            let p = o.sample_online_excluding(&exclude, &mut rng).unwrap();
            assert!(!exclude.contains(&p));
            assert!(o.is_online(p));
        }
        // Everyone but one excluded peer offline -> only that peer drawable.
        for p in 4..12 {
            o.depart(p, 1.0);
        }
        o.depart(0, 1.0);
        assert_eq!(o.online_count(), 3); // 1, 2, 3 online, all excluded
        assert_eq!(o.sample_online_excluding(&exclude, &mut rng), None);
        o.join(4, 2.0);
        assert_eq!(o.sample_online_excluding(&exclude, &mut rng), Some(4));
    }

    #[test]
    fn dense_set_and_ring_stay_consistent_under_churn() {
        // Random depart/join storm; every step the three views (peer
        // flags, dense vector, sorted ring) must agree exactly.
        let (mut o, mut rng) = mk(50);
        let mut t = 0.0;
        for _ in 0..2000 {
            t += 1.0;
            let p = rng.next_below(50) as usize;
            if o.is_online(p) {
                if o.online_count() > 1 {
                    o.depart(p, t);
                }
            } else {
                o.join(p, t);
            }
        }
        let by_flag: Vec<PeerId> = (0..50).filter(|&p| o.is_online(p)).collect();
        let mut by_dense: Vec<PeerId> = o.sample_online(o.online_count(), &mut rng).unwrap();
        by_dense.sort_unstable();
        let mut by_ring: Vec<PeerId> = o.online_ids().collect();
        by_ring.sort_unstable();
        assert_eq!(by_flag, by_dense);
        assert_eq!(by_flag, by_ring);
        assert_eq!(o.online_count(), by_flag.len());
    }

    #[test]
    fn churn_journal_records_and_compacts() {
        let (mut o, _) = mk(8);
        assert_eq!(o.churn_seq(), 0);
        assert!(o.churn_events_since(0).is_empty());
        o.depart(3, 1.0);
        o.depart(5, 2.0);
        o.join(3, 3.0);
        assert_eq!(o.churn_seq(), 3);
        let evs = o.churn_events_since(0);
        assert_eq!(
            evs,
            &[
                ChurnEvent { peer: 3, online: false },
                ChurnEvent { peer: 5, online: false },
                ChurnEvent { peer: 3, online: true },
            ]
        );
        // Partial replay from a cursor.
        assert_eq!(o.churn_events_since(2), &[ChurnEvent { peer: 3, online: true }]);
        // Compaction keeps absolute numbering intact.
        o.compact_churn(2);
        assert_eq!(o.churn_seq(), 3);
        assert_eq!(o.churn_events_since(2), &[ChurnEvent { peer: 3, online: true }]);
        o.compact_churn(o.churn_seq());
        assert!(o.churn_events_since(o.churn_seq()).is_empty());
        o.depart(1, 4.0);
        assert_eq!(o.churn_seq(), 4);
        assert_eq!(o.churn_events_since(3), &[ChurnEvent { peer: 1, online: false }]);
    }

    #[test]
    fn tokens_distinguish_instances() {
        let (a, _) = mk(4);
        let (b, _) = mk(4);
        assert_ne!(a.token(), 0);
        assert_ne!(a.token(), b.token());
    }

    #[test]
    fn successors_from_matches_collecting_successors() {
        let (mut o, _) = mk(32);
        o.depart(7, 1.0);
        for p in [0usize, 3, 12, 31] {
            for k in [1usize, 4, 9] {
                let collected = o.successors(p, k);
                let streamed: Vec<PeerId> = o.successors_from(p, k).collect();
                assert_eq!(collected, streamed, "p={p} k={k}");
            }
        }
    }

    #[test]
    fn fingers_nonempty_and_online() {
        let (o, _) = mk(64);
        let f = o.fingers(0);
        assert!(f.len() >= 4, "fingers {len}", len = f.len());
        assert!(f.iter().all(|&q| o.is_online(q)));
    }
}
