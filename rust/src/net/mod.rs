//! The P2P substrate: a Chord-style DHT overlay with churn-aware
//! stabilization, greedy multi-hop routing, and a latency/bandwidth model.
//!
//! This is the substrate the paper assumes from its companion systems
//! (P2P-DVM \[16\], MPI-over-P2P \[14\]): peers indexed in a DHT, neighbour
//! failures detected during stabilization (the observations feeding the
//! Eq. 1 estimator), messages routed in multiple decentralized hops.

pub mod bandwidth;
pub mod detector;
pub mod faults;
pub mod overlay;
pub mod routing;
pub mod stabilize;

pub use bandwidth::{BandwidthModel, LinkSpeed};
pub use detector::{DetectorSpec, SwimDetector};
pub use faults::{FaultPlane, FaultSpec, TransferFaults};
pub use overlay::{Overlay, PeerId, PeerState};
pub use routing::RouteOutcome;
pub use stabilize::{FailureObservation, Stabilizer};
