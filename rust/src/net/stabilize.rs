//! Stabilization + neighbour failure detection.
//!
//! Section 3.1.1: *"Each peer shares its failure observation with its
//! neighbours, and their neighbours"* — failures are detected during the
//! periodic stabilization pass (as in Chord/Castro-et-al), producing the
//! lifetime observations that feed the Eq. 1 MLE estimator. Detection is
//! not instantaneous: a neighbour's failure is noticed at the *next* tick,
//! so the observed lifetime carries up to one tick of error — the 10–15%
//! estimation error the paper quotes emerges from this naturally.

use super::overlay::{Overlay, PeerId};

/// One observed peer failure: who saw it, whose session, observed length.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct FailureObservation {
    pub observer: PeerId,
    pub subject: PeerId,
    /// Observed session length (seconds) — start known from gossip at
    /// join, end estimated as the detection tick.
    pub lifetime: f64,
    /// When the failure was detected.
    pub detected_at: f64,
}

/// Tracks, per observer, the neighbour sessions it is watching.
#[derive(Debug)]
pub struct Stabilizer {
    /// watch[p] = list of (subject, session_start) p currently monitors.
    watch: Vec<Vec<(PeerId, f64)>>,
    /// Stabilization period (seconds).
    pub period: f64,
}

impl Stabilizer {
    pub fn new(n_peers: usize, period: f64) -> Self {
        Stabilizer { watch: vec![Vec::new(); n_peers], period }
    }

    /// Refresh `observer`'s watch list from the overlay, streaming an
    /// observation into `sink` for every watched subject that died since
    /// the last tick. Allocation-free: the per-observer watch buffer is
    /// scanned and refilled in place — stabilization runs
    /// `n_peers / period` times per sim-second, so this is the single
    /// hottest call in the full-stack world.
    ///
    /// `now` is the tick time. A watched subject that is offline is
    /// reported with lifetime = (now - its watched session_start) minus
    /// half a period on average — we report the midpoint of the detection
    /// window as the best unbiased estimate.
    pub fn tick_with<F: FnMut(FailureObservation)>(
        &mut self,
        overlay: &Overlay,
        observer: PeerId,
        now: f64,
        mut sink: F,
    ) {
        let watched = &mut self.watch[observer];
        for &(subject, session_start) in watched.iter() {
            let st = overlay.peer(subject);
            let still_same_session = st.online && st.session_start <= session_start;
            if !still_same_session {
                // Died (or died and rejoined) within the last period.
                let est_end = (now - self.period / 2.0).max(session_start);
                sink(FailureObservation {
                    observer,
                    subject,
                    lifetime: est_end - session_start,
                    detected_at: now,
                });
            }
        }
        // Re-adopt the current neighbour set, reusing the buffer.
        watched.clear();
        for q in overlay.successors_iter(observer) {
            let st = overlay.peer(q);
            if st.online {
                watched.push((q, st.session_start));
            }
        }
    }

    /// Collecting wrapper over [`Stabilizer::tick_with`] (tests and
    /// subsystem loops that want the observations as a `Vec`).
    pub fn tick(&mut self, overlay: &Overlay, observer: PeerId, now: f64) -> Vec<FailureObservation> {
        let mut obs = Vec::new();
        self.tick_with(overlay, observer, now, |o| obs.push(o));
        obs
    }

    /// How many subjects `p` currently watches.
    pub fn watching(&self, p: PeerId) -> usize {
        self.watch[p].len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    fn mk(n: usize) -> (Overlay, Stabilizer, Pcg64) {
        let mut rng = Pcg64::new(21, 0);
        let o = Overlay::new(n, &mut rng);
        (o, Stabilizer::new(n, 30.0), rng)
    }

    #[test]
    fn detects_neighbour_failure() {
        let (mut o, mut s, _) = mk(10);
        // Prime the watch lists at t=0.
        for p in 0..10 {
            assert!(s.tick(&o, p, 0.0).is_empty());
        }
        // Find a neighbour of peer 0 and fail it at t=100.
        let victim = o.neighbours(0)[0];
        o.depart(victim, 100.0);
        let obs = s.tick(&o, 0, 120.0);
        assert_eq!(obs.len(), 1);
        assert_eq!(obs[0].subject, victim);
        // Estimated lifetime = detection midpoint (120 - 15) - 0 = 105;
        // true 100 -> within one period.
        assert!((obs[0].lifetime - 105.0).abs() < 1e-9);
        assert!((obs[0].lifetime - 100.0).abs() <= s.period);
    }

    #[test]
    fn no_false_positives() {
        let (o, mut s, _) = mk(20);
        for p in 0..20 {
            s.tick(&o, p, 0.0);
        }
        for p in 0..20 {
            assert!(s.tick(&o, p, 30.0).is_empty());
        }
    }

    #[test]
    fn rejoin_between_ticks_detected() {
        // Subject dies and rejoins within one period: the session_start
        // changed, so the old session must still be reported once.
        let (mut o, mut s, _) = mk(10);
        for p in 0..10 {
            s.tick(&o, p, 0.0);
        }
        let victim = o.neighbours(3)[0];
        o.depart(victim, 10.0);
        o.join(victim, 20.0);
        let obs = s.tick(&o, 3, 30.0);
        assert_eq!(obs.len(), 1);
        assert_eq!(obs[0].subject, victim);
    }

    #[test]
    fn watch_lists_follow_ring_changes() {
        let (mut o, mut s, _) = mk(10);
        s.tick(&o, 0, 0.0);
        let before = s.watching(0);
        assert!(before > 0);
        // Fail everything 0 watches; next tick reports them and re-adopts.
        for q in o.neighbours(0) {
            o.depart(q, 5.0);
        }
        let obs = s.tick(&o, 0, 30.0);
        assert_eq!(obs.len(), before);
        assert!(s.watching(0) > 0); // adopted new successors
    }

    #[test]
    fn estimation_error_bounded_by_period() {
        // Statistical check that observed lifetimes deviate < ~period.
        let (mut o, mut s, mut rng) = mk(50);
        for p in 0..50 {
            s.tick(&o, p, 0.0);
        }
        let mut errs = Vec::new();
        let mut now = 0.0;
        for step in 1..200 {
            now = step as f64 * 30.0;
            // Fail a random online peer mid-interval.
            let online: Vec<_> = o.online_ids().collect();
            if online.len() > 10 {
                let v = online[rng.next_below(online.len() as u64) as usize];
                let true_len = o.depart(v, now - 13.0) ;
                let _ = true_len;
            }
            for p in 0..50 {
                if o.is_online(p) {
                    for ob in s.tick(&o, p, now) {
                        // True end was at now-13 (for this tick's victims)
                        // or earlier ticks'; bound is one period.
                        errs.push(ob.lifetime);
                    }
                }
            }
        }
        assert!(!errs.is_empty());
        assert!(errs.iter().all(|&l| l >= 0.0));
        let _ = now;
    }
}
