//! Per-peer asymmetric bandwidth model.
//!
//! Checkpoint images are uploaded to (and downloaded from) the DHT store;
//! volunteer peers are consumer DSL/cable-like links, so upstream is the
//! scarce resource (the paper's Section 3.1.2 point that uploads slow the
//! message passing down). Speeds are sampled log-normally per peer so a
//! job's effective V / T_d is set by its slowest member — exactly the
//! "approximated as the required time for the slowest node" remark in
//! Section 4.2.

use crate::util::rng::Pcg64;

/// A peer's link capacity in bytes/second.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct LinkSpeed {
    pub up_bps: f64,
    pub down_bps: f64,
}

/// Population model for link speeds.
#[derive(Debug, Clone, Copy)]
pub struct BandwidthModel {
    /// Median upstream (bytes/s). Default ~= 1 Mbit/s up.
    pub up_median: f64,
    /// Median downstream (bytes/s). Default ~= 8 Mbit/s down.
    pub down_median: f64,
    /// Log-normal sigma of the spread across peers.
    pub sigma: f64,
}

impl Default for BandwidthModel {
    fn default() -> Self {
        BandwidthModel {
            up_median: 1_000_000.0 / 8.0 * 1.0,  // 1 Mbit/s
            down_median: 1_000_000.0 / 8.0 * 8.0, // 8 Mbit/s
            sigma: 0.5,
        }
    }
}

impl BandwidthModel {
    /// Sample one peer's link.
    pub fn sample(&self, rng: &mut Pcg64) -> LinkSpeed {
        LinkSpeed {
            up_bps: rng.lognormal(self.up_median, self.sigma),
            down_bps: rng.lognormal(self.down_median, self.sigma),
        }
    }

    /// Sample a whole population.
    pub fn sample_population(&self, n: usize, rng: &mut Pcg64) -> Vec<LinkSpeed> {
        (0..n).map(|_| self.sample(rng)).collect()
    }
}

impl LinkSpeed {
    /// Seconds to upload `bytes`.
    pub fn upload_time(&self, bytes: f64) -> f64 {
        bytes / self.up_bps.max(1.0)
    }

    /// Seconds to download `bytes`.
    pub fn download_time(&self, bytes: f64) -> f64 {
        bytes / self.down_bps.max(1.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn medians_roughly_match() {
        let m = BandwidthModel::default();
        let mut rng = Pcg64::new(8, 0);
        let pop = m.sample_population(20_001, &mut rng);
        let mut ups: Vec<f64> = pop.iter().map(|l| l.up_bps).collect();
        ups.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let med = ups[ups.len() / 2];
        assert!(
            (med - m.up_median).abs() < m.up_median * 0.05,
            "median {med} vs {}",
            m.up_median
        );
    }

    #[test]
    fn asymmetric() {
        let m = BandwidthModel::default();
        let mut rng = Pcg64::new(9, 0);
        let pop = m.sample_population(1000, &mut rng);
        let up: f64 = pop.iter().map(|l| l.up_bps).sum();
        let down: f64 = pop.iter().map(|l| l.down_bps).sum();
        assert!(down > 4.0 * up, "down {down} vs up {up}");
    }

    #[test]
    fn transfer_times() {
        let l = LinkSpeed { up_bps: 125_000.0, down_bps: 1_000_000.0 };
        assert!((l.upload_time(1_250_000.0) - 10.0).abs() < 1e-9);
        assert!((l.download_time(1_000_000.0) - 1.0).abs() < 1e-9);
    }
}
