//! Deterministic fault-injection plane.
//!
//! Every fault draws from a *dedicated* seeded RNG stream (never the
//! world's main `0xB0B` stream), so the `faults: none` default consumes
//! nothing and the fault-free world is bit-exact with the tree before
//! this module existed. Fault kinds compose through the registry key
//! grammar (`loss:0.05+partition:600:300:0.3`):
//!
//! * `loss:P` — each control-plane probe / data-plane transfer attempt
//!   is independently dropped with probability `P`.
//! * `delay:MEAN` — control-plane probes pick up an exponential
//!   round-trip delay with the given mean; a probe whose round trip
//!   exceeds its implicit ack window counts as failed (no extra event
//!   machinery, but delay gets a real effect on detection).
//! * `partition:START:DUR:FRAC` — at sim-time `START` (measured from
//!   world construction) a random `FRAC` of the population is cut off
//!   from the rest (and from the server, which sits on the majority
//!   side) for `DUR` seconds, then the cut heals. Membership of the
//!   minority side comes from its own seeded stream, so it is a pure
//!   function of `(seed, n_peers)`.
//! * `crash:MTBF:DOWN` — Poisson crash-restarts on top of the churn
//!   model: a random online peer hard-crashes (exponential inter-crash
//!   time with mean `MTBF`) and rejoins after exactly `DOWN` seconds
//!   with its stored chunks intact — the data-plane's churn-journal
//!   replay revives the rejoining holder's groups automatically. The
//!   crashed peer's original session-end `PeerFail` timer is left in
//!   place and treated as ordinary extra churn when it fires.
//!
//! Transfer-level loss is retried with bounded exponential backoff
//! (deterministic jitter from the transfer fault stream); see
//! [`TransferFaults::backoff`] and `dataplane/transfer.rs`.

use crate::error::{Error, Result};
use crate::util::rng::Pcg64;

/// RNG stream ids — distinct from the world's `0xB0B` main stream.
pub const FAULT_PLANE_STREAM: u64 = 0xFA17;
pub const TRANSFER_FAULT_STREAM: u64 = 0xDA7A;
pub const PARTITION_SIDE_STREAM: u64 = 0x51DE;

/// A scheduled network partition (`partition:START:DUR:FRAC`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct PartitionSpec {
    /// Seconds after world construction the cut opens.
    pub start: f64,
    /// Seconds the cut stays open.
    pub duration: f64,
    /// Expected fraction of the population on the minority side.
    pub frac: f64,
}

/// Poisson crash-restart injection (`crash:MTBF:DOWN`).
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct CrashSpec {
    /// Mean seconds between injected crashes (population-wide).
    pub mtbf: f64,
    /// Fixed downtime before the crashed peer rejoins with its image.
    pub downtime: f64,
}

/// Composable fault-injection configuration (the `faults:` registry
/// axis). The default is no faults at all.
#[derive(Debug, Clone, Copy, PartialEq, Default)]
pub struct FaultSpec {
    /// Independent drop probability per probe / transfer attempt.
    pub loss: Option<f64>,
    /// Mean one-way exponential probe delay (control plane only).
    pub delay: Option<f64>,
    pub partition: Option<PartitionSpec>,
    pub crash: Option<CrashSpec>,
}

fn num(x: f64) -> String {
    format!("{x}")
}

fn parse_num(key: &str, part: &str) -> Result<f64> {
    part.parse::<f64>().map_err(|_| {
        Error::Config(format!("faults key `{key}`: `{part}` is not a number"))
    })
}

impl FaultSpec {
    /// Is this the fault-free default?
    pub fn is_none(&self) -> bool {
        *self == FaultSpec::default()
    }

    /// Canonical registry key. Round-trips exactly through [`parse`]
    /// (`FaultSpec::parse`): fault kinds always serialize in
    /// loss, delay, partition, crash order.
    pub fn key(&self) -> String {
        if self.is_none() {
            return "none".into();
        }
        let mut parts = Vec::new();
        if let Some(p) = self.loss {
            parts.push(format!("loss:{}", num(p)));
        }
        if let Some(d) = self.delay {
            parts.push(format!("delay:{}", num(d)));
        }
        if let Some(p) = self.partition {
            parts.push(format!(
                "partition:{}:{}:{}",
                num(p.start),
                num(p.duration),
                num(p.frac)
            ));
        }
        if let Some(c) = self.crash {
            parts.push(format!("crash:{}:{}", num(c.mtbf), num(c.downtime)));
        }
        parts.join("+")
    }

    /// Parse a composable fault key: `none`, or `+`-joined parts of
    /// `loss:P`, `delay:MEAN`, `partition:START:DUR:FRAC`,
    /// `crash:MTBF:DOWN`. Each kind may appear at most once.
    pub fn parse(key: &str) -> Result<FaultSpec> {
        if key == "none" {
            return Ok(FaultSpec::default());
        }
        let mut spec = FaultSpec::default();
        for part in key.split('+') {
            let fields: Vec<&str> = part.split(':').collect();
            let dup = |name: &str| {
                Error::Config(format!("faults key `{key}`: `{name}` given twice"))
            };
            match fields.as_slice() {
                ["loss", p] => {
                    if spec.loss.is_some() {
                        return Err(dup("loss"));
                    }
                    spec.loss = Some(parse_num(key, p)?);
                }
                ["delay", d] => {
                    if spec.delay.is_some() {
                        return Err(dup("delay"));
                    }
                    spec.delay = Some(parse_num(key, d)?);
                }
                ["partition", start, dur, frac] => {
                    if spec.partition.is_some() {
                        return Err(dup("partition"));
                    }
                    spec.partition = Some(PartitionSpec {
                        start: parse_num(key, start)?,
                        duration: parse_num(key, dur)?,
                        frac: parse_num(key, frac)?,
                    });
                }
                ["crash", mtbf, down] => {
                    if spec.crash.is_some() {
                        return Err(dup("crash"));
                    }
                    spec.crash = Some(CrashSpec {
                        mtbf: parse_num(key, mtbf)?,
                        downtime: parse_num(key, down)?,
                    });
                }
                _ => {
                    return Err(Error::Config(format!(
                        "unknown faults key part `{part}` in `{key}` — want none | loss:P | \
                         delay:MEAN | partition:START:DUR:FRAC | crash:MTBF:DOWN, joined with `+`"
                    )))
                }
            }
        }
        spec.validated()
    }

    /// Probe-drop decision against a *caller-supplied* RNG stream — the
    /// sharded world evaluates probe faults on each prober's own
    /// per-peer stream so the outcome is independent of how the world
    /// is partitioned. Draw order mirrors [`FaultPlane::drop_probe`]
    /// exactly: partition cut (pure, consumes nothing), then loss (one
    /// uniform draw), then delay (two exponential draws checked against
    /// the implicit ack `window`).
    pub fn drop_probe_with(
        &self,
        partition: Option<&PartitionSchedule>,
        rng: &mut Pcg64,
        now: f64,
        src: usize,
        dst: usize,
        window: f64,
    ) -> bool {
        if let Some(ps) = partition {
            if ps.cuts(now, Some(src), Some(dst)) {
                return true;
            }
        }
        if let Some(p) = self.loss {
            if rng.next_f64() < p {
                return true;
            }
        }
        if let Some(mean) = self.delay {
            let rtt = rng.exp(1.0 / mean) + rng.exp(1.0 / mean);
            if rtt > window {
                return true;
            }
        }
        false
    }

    /// Range-check every configured fault kind.
    pub fn validated(self) -> Result<FaultSpec> {
        if let Some(p) = self.loss {
            if !(0.0..1.0).contains(&p) {
                return Err(Error::Config(format!("faults loss {p} must be in [0, 1)")));
            }
        }
        if let Some(d) = self.delay {
            if !(d > 0.0) {
                return Err(Error::Config(format!("faults delay mean {d} must be > 0")));
            }
        }
        if let Some(p) = self.partition {
            if p.start < 0.0 || !(p.duration > 0.0) || !(p.frac > 0.0 && p.frac < 1.0) {
                return Err(Error::Config(format!(
                    "faults partition start {} / duration {} / frac {} out of range \
                     (start >= 0, duration > 0, 0 < frac < 1)",
                    p.start, p.duration, p.frac
                )));
            }
        }
        if let Some(c) = self.crash {
            if !(c.mtbf > 0.0) || c.downtime < 0.0 {
                return Err(Error::Config(format!(
                    "faults crash mtbf {} must be > 0 and downtime {} >= 0",
                    c.mtbf, c.downtime
                )));
            }
        }
        Ok(self)
    }
}

/// Materialized partition: which peers sit on the minority side, as a
/// pure function of `(seed, n_peers)` via the dedicated side stream.
/// The server is always on the majority side.
#[derive(Debug, Clone)]
pub struct PartitionSchedule {
    pub start: f64,
    pub duration: f64,
    side: Vec<bool>,
}

impl PartitionSchedule {
    pub fn new(spec: &PartitionSpec, n_peers: usize, seed: u64) -> PartitionSchedule {
        let mut rng = Pcg64::new(seed, PARTITION_SIDE_STREAM);
        let side = (0..n_peers).map(|_| rng.next_f64() < spec.frac).collect();
        PartitionSchedule { start: spec.start, duration: spec.duration, side }
    }

    /// Is the cut open at `now`?
    pub fn active(&self, now: f64) -> bool {
        now >= self.start && now < self.start + self.duration
    }

    /// Absolute sim-time the cut heals.
    pub fn heal_at(&self) -> f64 {
        self.start + self.duration
    }

    /// Is `p` on the minority side?
    pub fn minority(&self, p: usize) -> bool {
        self.side.get(p).copied().unwrap_or(false)
    }

    pub fn minority_count(&self) -> usize {
        self.side.iter().filter(|&&s| s).count()
    }

    /// Does traffic between `a` and `b` cross the cut at `now`?
    /// `None` is the server (majority side).
    pub fn cuts(&self, now: f64, a: Option<usize>, b: Option<usize>) -> bool {
        if !self.active(now) {
            return false;
        }
        let sa = a.map(|p| self.minority(p)).unwrap_or(false);
        let sb = b.map(|p| self.minority(p)).unwrap_or(false);
        sa != sb
    }
}

/// Control-plane fault injector: probe drops for the SWIM detector plus
/// the crash-restart schedule. One dedicated RNG stream (`0xFA17`);
/// draws happen only for the fault kinds actually configured, in event
/// order, so consumption is deterministic.
#[derive(Debug)]
pub struct FaultPlane {
    spec: FaultSpec,
    partition: Option<PartitionSchedule>,
    rng: Pcg64,
}

impl FaultPlane {
    pub fn new(spec: FaultSpec, n_peers: usize, seed: u64) -> FaultPlane {
        let partition =
            spec.partition.as_ref().map(|p| PartitionSchedule::new(p, n_peers, seed));
        FaultPlane { spec, partition, rng: Pcg64::new(seed, FAULT_PLANE_STREAM) }
    }

    pub fn spec(&self) -> &FaultSpec {
        &self.spec
    }

    pub fn partition(&self) -> Option<&PartitionSchedule> {
        self.partition.as_ref()
    }

    /// Does a control-plane probe from `src` to `dst` fail? A probe
    /// fails on a partition cut, an independent loss draw, or (with
    /// `delay:` configured) a round trip exceeding the prober's implicit
    /// ack window of `window` seconds.
    pub fn drop_probe(&mut self, now: f64, src: usize, dst: usize, window: f64) -> bool {
        let spec = self.spec;
        spec.drop_probe_with(self.partition.as_ref(), &mut self.rng, now, src, dst, window)
    }

    /// Uniform draw from the fault stream (crash victim selection).
    pub fn draw_below(&mut self, n: u64) -> u64 {
        self.rng.next_below(n)
    }

    /// Exponential draw from the fault stream (crash inter-arrival).
    pub fn draw_exp(&mut self, rate: f64) -> f64 {
        self.rng.exp(rate)
    }
}

/// Data-plane fault injector: per-attempt transfer drops + the bounded
/// exponential backoff schedule. `None` when neither loss nor a
/// partition is configured, so the fault-free transfer path stays
/// exactly the pre-fault-plane code.
#[derive(Debug, Clone)]
pub struct TransferFaults {
    loss: f64,
    partition: Option<PartitionSchedule>,
    /// Attempts beyond the first before a transfer aborts.
    pub max_retries: u32,
    /// Base backoff (seconds) for the first retry.
    pub backoff_base: f64,
    rng: Pcg64,
}

impl TransferFaults {
    pub fn new(spec: &FaultSpec, n_peers: usize, seed: u64) -> Option<TransferFaults> {
        if spec.loss.is_none() && spec.partition.is_none() {
            return None;
        }
        Some(TransferFaults {
            loss: spec.loss.unwrap_or(0.0),
            partition: spec.partition.as_ref().map(|p| PartitionSchedule::new(p, n_peers, seed)),
            max_retries: 6,
            backoff_base: 1.0,
            rng: Pcg64::new(seed, TRANSFER_FAULT_STREAM),
        })
    }

    /// Is this transfer attempt blocked? `None` endpoints are the
    /// server. A partition cut blocks without consuming a draw; loss
    /// consumes exactly one draw per attempt.
    pub fn blocks(&mut self, now: f64, src: Option<usize>, dst: Option<usize>) -> bool {
        if let Some(ps) = &self.partition {
            if ps.cuts(now, src, dst) {
                return true;
            }
        }
        self.loss > 0.0 && self.rng.next_f64() < self.loss
    }

    /// Backoff before retry `attempt` (1-based): bounded exponential
    /// with deterministic jitter in `[1.0, 1.5)` from the seeded stream.
    pub fn backoff(&mut self, attempt: u32) -> f64 {
        let exp = 2f64.powi(attempt.saturating_sub(1).min(16) as i32);
        self.backoff_base * exp * (1.0 + 0.5 * self.rng.next_f64())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn key_round_trips_every_composition() {
        for key in [
            "none",
            "loss:0.05",
            "delay:2",
            "partition:600:300:0.3",
            "crash:1800:120",
            "loss:0.1+delay:1.5",
            "loss:0.05+partition:600:300:0.3",
            "loss:0.02+delay:0.5+partition:100:50:0.25+crash:3600:60",
        ] {
            let spec = FaultSpec::parse(key).unwrap();
            assert_eq!(spec.key(), key, "canonical key must round-trip");
            assert_eq!(FaultSpec::parse(&spec.key()).unwrap(), spec);
        }
    }

    #[test]
    fn parse_rejects_malformed_and_out_of_range() {
        for bad in [
            "loss",
            "loss:2",
            "loss:x",
            "delay:0",
            "partition:600:300",
            "partition:-1:300:0.3",
            "partition:600:300:1.5",
            "crash:0:60",
            "loss:0.1+loss:0.2",
            "jitter:5",
            "",
        ] {
            assert!(FaultSpec::parse(bad).is_err(), "`{bad}` must not parse");
        }
    }

    #[test]
    fn partition_sides_are_seed_stable_and_server_is_majority() {
        let spec = PartitionSpec { start: 100.0, duration: 50.0, frac: 0.3 };
        let a = PartitionSchedule::new(&spec, 500, 42);
        let b = PartitionSchedule::new(&spec, 500, 42);
        let m = a.minority_count();
        assert!(m > 500 * 15 / 100 && m < 500 * 45 / 100, "minority {m}/500 far from 30%");
        for p in 0..500 {
            assert_eq!(a.minority(p), b.minority(p), "side of {p} must be seed-stable");
        }
        // Cut semantics: active window only, server on the majority side.
        let minority = (0..500).find(|&p| a.minority(p)).unwrap();
        let majority = (0..500).find(|&p| !a.minority(p)).unwrap();
        assert!(a.cuts(120.0, Some(minority), Some(majority)));
        assert!(a.cuts(120.0, Some(minority), None), "minority cut off from the server");
        assert!(!a.cuts(120.0, Some(majority), None));
        assert!(!a.cuts(99.0, Some(minority), Some(majority)), "before start");
        assert!(!a.cuts(151.0, Some(minority), Some(majority)), "after heal");
        assert!(!a.cuts(120.0, Some(minority), Some(minority)), "same side");
    }

    #[test]
    fn fault_plane_probe_drops_follow_the_spec() {
        let spec = FaultSpec::parse("loss:0.2").unwrap();
        let mut fp = FaultPlane::new(spec, 100, 7);
        let drops = (0..10_000).filter(|_| fp.drop_probe(0.0, 1, 2, 5.0)).count();
        let frac = drops as f64 / 10_000.0;
        assert!((frac - 0.2).abs() < 0.02, "loss frac {frac} vs 0.2");
        // No faults -> no drops and no RNG consumption.
        let mut quiet = FaultPlane::new(FaultSpec::default(), 100, 7);
        assert!((0..1000).all(|_| !quiet.drop_probe(0.0, 1, 2, 5.0)));
    }

    #[test]
    fn drop_probe_with_matches_fault_plane_stream_for_stream() {
        let spec = FaultSpec::parse("loss:0.1+delay:1.5+partition:50:100:0.3").unwrap();
        let mut fp = FaultPlane::new(spec, 64, 11);
        let schedule = PartitionSchedule::new(&spec.partition.unwrap(), 64, 11);
        let mut rng = Pcg64::new(11, FAULT_PLANE_STREAM);
        for i in 0..2000usize {
            let now = i as f64 * 0.1;
            let (src, dst) = (i % 64, (i * 7 + 1) % 64);
            let a = fp.drop_probe(now, src, dst, 5.0);
            let b = spec.drop_probe_with(Some(&schedule), &mut rng, now, src, dst, 5.0);
            assert_eq!(a, b, "probe {i}: plane and caller-rng helper diverged");
        }
    }

    #[test]
    fn transfer_faults_none_for_fault_free_and_delay_only() {
        assert!(TransferFaults::new(&FaultSpec::default(), 10, 1).is_none());
        let delay_only = FaultSpec::parse("delay:2").unwrap();
        assert!(
            TransferFaults::new(&delay_only, 10, 1).is_none(),
            "probe delay must not touch the data-plane transfer path"
        );
        assert!(TransferFaults::new(&FaultSpec::parse("loss:0.1").unwrap(), 10, 1).is_some());
    }

    #[test]
    fn backoff_is_bounded_exponential_with_jitter() {
        let spec = FaultSpec::parse("loss:0.5").unwrap();
        let mut tf = TransferFaults::new(&spec, 10, 3).unwrap();
        let b1 = tf.backoff(1);
        let b2 = tf.backoff(2);
        let b3 = tf.backoff(3);
        assert!((1.0..1.5).contains(&b1), "attempt 1 backoff {b1}");
        assert!((2.0..3.0).contains(&b2), "attempt 2 backoff {b2}");
        assert!((4.0..6.0).contains(&b3), "attempt 3 backoff {b3}");
        // Identical seed => identical jitter sequence.
        let mut tf2 = TransferFaults::new(&spec, 10, 3).unwrap();
        assert_eq!(tf2.backoff(1), b1);
        assert_eq!(tf2.backoff(2), b2);
    }
}
