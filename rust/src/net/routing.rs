//! Greedy DHT routing: hop-by-hop towards the key owner, counting hops and
//! accumulating latency. The simulator uses the outcome to time message
//! delivery; the workflow experiments use the hop counts to account
//! server-mediated vs P2P-mediated I/O (Fig. 1(a) vs 1(b)).

use super::overlay::{Overlay, PeerId};
use crate::util::rng::Pcg64;

/// Result of routing one message through the overlay.
#[derive(Debug, Clone, PartialEq)]
pub struct RouteOutcome {
    pub src: PeerId,
    pub dst: PeerId,
    pub hops: u32,
    /// End-to-end latency (seconds).
    pub latency: f64,
    /// Every peer the message transited (including src and dst).
    pub path: Vec<PeerId>,
}

/// Per-hop latency model: base + exponential jitter (seconds).
#[derive(Debug, Clone, Copy)]
pub struct HopLatency {
    pub base: f64,
    pub jitter_mean: f64,
}

impl Default for HopLatency {
    fn default() -> Self {
        // Internet-ish: 40 ms base + 20 ms mean jitter per hop.
        HopLatency { base: 0.040, jitter_mean: 0.020 }
    }
}

impl HopLatency {
    pub fn sample(&self, rng: &mut Pcg64) -> f64 {
        self.base + rng.exp(1.0 / self.jitter_mean.max(1e-9))
    }
}

/// Route greedily from `src` towards the owner of `key`.
///
/// Each hop jumps to the routing-table entry (finger or successor) that is
/// closest to the key without overshooting — the Chord invariant that
/// guarantees O(log n) hops. Returns `None` if the overlay has no online
/// peers or `src` is offline.
pub fn route(
    overlay: &Overlay,
    src: PeerId,
    key: u64,
    lat: HopLatency,
    rng: &mut Pcg64,
) -> Option<RouteOutcome> {
    if !overlay.is_online(src) {
        return None;
    }
    let dst = overlay.owner_of(key)?;
    let mut cur = src;
    let mut path = vec![src];
    let mut latency = 0.0;
    let mut hops = 0u32;
    // Distance clockwise from a ring id to the key.
    let dist = |rid: u64| key.wrapping_sub(rid);
    while cur != dst {
        // Candidates: the fingers that can actually help are the owners of
        // `base + 2^j` for the top few j with `2^j <= clockwise gap` (any
        // larger overshoots, any smaller is dominated) — so 4 ring lookups
        // replace the naive 64-finger scan — plus the successor list.
        let mut best = cur;
        let mut best_d = dist(overlay.peer(cur).ring_id);
        let base = overlay.peer(cur).ring_id;
        let gap = best_d;
        let consider = |q: PeerId, best: &mut PeerId, best_d: &mut u64| {
            if q != cur {
                let d = dist(overlay.peer(q).ring_id);
                if d < *best_d {
                    *best = q;
                    *best_d = d;
                }
            }
        };
        if gap > 1 {
            let top = 63 - gap.leading_zeros();
            for j in (top.saturating_sub(3)..=top).rev() {
                if let Some(q) = overlay.owner_of(base.wrapping_add(1u64 << j)) {
                    consider(q, &mut best, &mut best_d);
                }
            }
        }
        for q in overlay.successors_iter(cur) {
            consider(q, &mut best, &mut best_d);
        }
        if best == cur {
            // No progress possible (tiny overlays): jump straight to owner,
            // which the successor ring can always reach in one more hop.
            best = dst;
        }
        cur = best;
        hops += 1;
        latency += lat.sample(rng);
        path.push(cur);
        if hops > 2 * 64 {
            // Routing loop would be an overlay invariant violation.
            return None;
        }
    }
    if hops == 0 {
        // src already owns the key: model a local delivery with zero hops.
        latency = 0.0;
    }
    Some(RouteOutcome { src, dst, hops, latency, path })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mk(n: usize) -> (Overlay, Pcg64) {
        let mut rng = Pcg64::new(7, 0);
        let o = Overlay::new(n, &mut rng);
        (o, rng)
    }

    #[test]
    fn routes_reach_owner() {
        let (o, mut rng) = mk(256);
        for _ in 0..200 {
            let key = rng.next_u64();
            let src = rng.next_below(256) as usize;
            let r = route(&o, src, key, HopLatency::default(), &mut rng).unwrap();
            assert_eq!(r.dst, o.owner_of(key).unwrap());
            assert_eq!(*r.path.last().unwrap(), r.dst);
            assert_eq!(r.path[0], src);
        }
    }

    #[test]
    fn hops_logarithmic() {
        let (o, mut rng) = mk(1024);
        let mut total = 0u32;
        let n = 300;
        for _ in 0..n {
            let key = rng.next_u64();
            let src = rng.next_below(1024) as usize;
            let r = route(&o, src, key, HopLatency::default(), &mut rng).unwrap();
            total += r.hops;
        }
        let avg = total as f64 / n as f64;
        // Chord: ~0.5 log2(n) = 5; greedy with fingers+successors stays
        // within a small factor.
        assert!(avg < 12.0, "avg hops {avg}");
        assert!(avg > 1.0, "avg hops {avg} suspiciously low");
    }

    #[test]
    fn latency_positive_and_scales_with_hops() {
        let (o, mut rng) = mk(512);
        let key = rng.next_u64();
        let r = route(&o, 0, key, HopLatency::default(), &mut rng).unwrap();
        if r.hops > 0 {
            assert!(r.latency >= 0.040 * r.hops as f64);
        }
    }

    #[test]
    fn offline_src_fails() {
        let (mut o, mut rng) = mk(16);
        o.depart(3, 1.0);
        assert!(route(&o, 3, 42, HopLatency::default(), &mut rng).is_none());
    }

    #[test]
    fn self_owned_key_zero_hops() {
        let (o, mut rng) = mk(8);
        // Key exactly at peer 0's ring id is owned by peer 0.
        let key = o.peer(0).ring_id;
        let r = route(&o, 0, key, HopLatency::default(), &mut rng).unwrap();
        assert_eq!(r.hops, 0);
        assert_eq!(r.latency, 0.0);
    }
}
