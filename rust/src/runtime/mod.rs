//! PJRT runtime: load AOT artifacts (HLO text lowered by
//! `python/compile/aot.py`), compile them once on the CPU PJRT client, and
//! execute them from the coordinator's hot path.
//!
//! Interchange is HLO *text* — jax ≥ 0.5 emits HloModuleProtos with 64-bit
//! instruction ids which the crate's xla_extension 0.5.1 rejects; the text
//! parser reassigns ids (see DESIGN.md and python/compile/aot.py).

use crate::error::{Error, Result};
use crate::util::json::{parse as json_parse, Json};
use crate::util::wall_clock;
use std::path::{Path, PathBuf};

/// Metadata written next to each artifact by aot.py.
#[derive(Debug, Clone, PartialEq)]
pub struct ArtifactMeta {
    /// Compiled batch dimension B.
    pub batch: usize,
    /// Lifetime-window dimension W (planner artifact only).
    pub window: usize,
    /// Rate-grid dimension G (usurface artifact only).
    pub grid: usize,
    pub dtype: String,
}

impl ArtifactMeta {
    pub fn from_json_file(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let j = json_parse(&text).map_err(Error::Runtime)?;
        let get = |k: &str| j.get(k).and_then(Json::as_usize).unwrap_or(0);
        Ok(ArtifactMeta {
            batch: get("batch"),
            window: get("window"),
            grid: get("grid"),
            dtype: j
                .get("dtype")
                .and_then(Json::as_str)
                .unwrap_or("f64")
                .to_string(),
        })
    }
}

/// A compiled artifact ready to execute.
pub struct LoadedModule {
    exe: xla::PjRtLoadedExecutable,
    pub meta: ArtifactMeta,
    pub name: String,
    /// Executions performed (perf accounting).
    pub executions: std::cell::Cell<u64>,
}

impl LoadedModule {
    /// Execute with f64 inputs given as (flat data, dims) pairs; returns
    /// the flattened f64 outputs of the result tuple.
    pub fn execute_f64(&self, inputs: &[(&[f64], &[i64])]) -> Result<Vec<Vec<f64>>> {
        let mut literals = Vec::with_capacity(inputs.len());
        for (data, dims) in inputs {
            let expect: i64 = dims.iter().product();
            if expect as usize != data.len() {
                return Err(Error::Runtime(format!(
                    "input shape {dims:?} wants {expect} elements, got {}",
                    data.len()
                )));
            }
            let lit = xla::Literal::vec1(data);
            let lit = if dims.len() == 1 { lit } else { lit.reshape(dims)? };
            literals.push(lit);
        }
        let result = self.exe.execute::<xla::Literal>(&literals)?[0][0].to_literal_sync()?;
        self.executions.set(self.executions.get() + 1);
        let parts = result.to_tuple()?;
        let mut out = Vec::with_capacity(parts.len());
        for p in parts {
            out.push(p.to_vec::<f64>()?);
        }
        Ok(out)
    }
}

/// The PJRT client + artifact loader (compile cache keyed by path).
pub struct PjrtRuntime {
    client: xla::PjRtClient,
    pub artifacts_dir: PathBuf,
}

impl PjrtRuntime {
    /// CPU client over the default artifacts directory (`artifacts/` next
    /// to the workspace root, overridable with `P2PCP_ARTIFACTS`).
    pub fn cpu() -> Result<Self> {
        Self::cpu_with_dir(default_artifacts_dir())
    }

    pub fn cpu_with_dir<P: Into<PathBuf>>(dir: P) -> Result<Self> {
        let client = xla::PjRtClient::cpu()?;
        Ok(PjrtRuntime { client, artifacts_dir: dir.into() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load `<name>.hlo.txt` + `<name>.meta.json` and compile.
    pub fn load(&self, name: &str) -> Result<LoadedModule> {
        let hlo = self.artifacts_dir.join(format!("{name}.hlo.txt"));
        let meta_path = self.artifacts_dir.join(format!("{name}.meta.json"));
        if !hlo.exists() {
            return Err(Error::Runtime(format!(
                "artifact {} not found — run `make artifacts`",
                hlo.display()
            )));
        }
        let proto = xla::HloModuleProto::from_text_file(&hlo)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp)?;
        let meta = ArtifactMeta::from_json_file(&meta_path)?;
        Ok(LoadedModule {
            exe,
            meta,
            name: name.to_string(),
            executions: std::cell::Cell::new(0),
        })
    }
}

/// Locate `artifacts/`: env override, else walk up from cwd. Host access
/// goes through `util::wall_clock`, the allowlisted boundary.
pub fn default_artifacts_dir() -> PathBuf {
    if let Some(dir) = wall_clock::env_var("P2PCP_ARTIFACTS") {
        return PathBuf::from(dir);
    }
    let mut cur = wall_clock::current_dir();
    loop {
        let cand = cur.join("artifacts");
        if cand.join("planner.hlo.txt").exists() {
            return cand;
        }
        if !cur.pop() {
            return PathBuf::from("artifacts");
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn meta_parses() {
        let dir = wall_clock::temp_dir().join("p2pcp_meta_test");
        std::fs::create_dir_all(&dir).unwrap();
        let p = dir.join("m.json");
        std::fs::write(&p, r#"{"batch": 256, "window": 64, "dtype": "f64"}"#).unwrap();
        let m = ArtifactMeta::from_json_file(&p).unwrap();
        assert_eq!(m.batch, 256);
        assert_eq!(m.window, 64);
        assert_eq!(m.grid, 0);
        assert_eq!(m.dtype, "f64");
    }

    #[test]
    fn missing_artifact_is_a_clear_error() {
        let rt = match PjrtRuntime::cpu_with_dir("/nonexistent-dir") {
            Ok(rt) => rt,
            Err(_) => return, // no PJRT on this host: nothing to check
        };
        let err = match rt.load("planner") {
            Err(e) => e,
            Ok(_) => panic!("load from /nonexistent-dir must fail"),
        };
        assert!(err.to_string().contains("make artifacts"), "{err}");
    }

    // Execution against the real artifact is covered by
    // rust/tests/planner_runtime.rs (integration; requires `make artifacts`).
}
