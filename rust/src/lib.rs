//! # p2pcp — Adaptive Checkpointing for P2P Volunteer-Computing Work Flows
//!
//! A framework for running message-passing work-flow jobs over a churning
//! peer-to-peer volunteer-computing substrate, reproducing
//! *Ni & Harwood, "An Adaptive Checkpointing Scheme for Peer-to-Peer Based
//! Volunteer Computing Work Flows"* (2007).
//!
//! The paper's contribution — a fully decentralized **adaptive checkpoint
//! interval** computed from online estimates of the peer failure rate `μ`
//! (Eq. 1, MLE), the checkpoint overhead `V` (Eq. 2) and the image download
//! overhead `T_d`, through the closed form
//!
//! ```text
//! λ* = kμ / ( W0[ (Vkμ − T_d·kμ − 1)·(T_d·kμ + 1)⁻¹·e⁻¹ ] + 1 )
//! ```
//!
//! — is integrated as a first-class [`policy::CheckpointPolicy`].
//!
//! ## Layering
//!
//! * **Construction layer** — [`scenario`]: the **single** construction
//!   surface for the whole crate. `Scenario::builder()` composes typed,
//!   pluggable component specs (churn, policy, estimator, planner,
//!   bandwidth, workload) with paper-faithful defaults; the
//!   [`scenario::registry`] maps string keys (`"adaptive"`,
//!   `"gnutella-trace"`, `"ewma:0.1"`, …) onto the same specs so CLI
//!   flags and config files resolve through one code path; and
//!   [`scenario::SweepRunner`] fans scenario grids across threads with
//!   deterministic, seed-keyed aggregation. The CLI, examples, benches,
//!   and experiment harness all build their stacks here.
//! * **L3 (this crate)** — discrete-event simulation core ([`sim`]), P2P
//!   overlay with churn and stabilization ([`net`], [`churn`]), replicated
//!   checkpoint storage ([`storage`]) behind the chunked checkpoint
//!   data-plane ([`dataplane`]: server / replicate / erasure placement,
//!   contention-aware transfers, repair, server I/O-offload accounting),
//!   failure-rate / overhead estimators
//!   ([`estimator`]), the analytic utilization model ([`model`]),
//!   checkpoint policies ([`policy`]), a message-passing substrate with
//!   Chandy–Lamport snapshots ([`mpi`]), the job coordinator and BOINC-style
//!   work pool ([`coordinator`], [`workflow`]), and the experiment harness
//!   ([`experiments`]).
//! * **L2/L1 (build-time python)** — the planner compute graph and Pallas
//!   kernels, AOT-lowered to `artifacts/*.hlo.txt` and executed from
//!   [`runtime`] / [`planner::XlaPlanner`] via the PJRT C API. Python never
//!   runs on the request path.

pub mod churn;
pub mod cli;
pub mod config;
pub mod coordinator;
pub mod dataplane;
pub mod error;
pub mod estimator;
pub mod experiments;
pub mod metrics;
pub mod model;
pub mod mpi;
pub mod net;
pub mod planner;
pub mod policy;
pub mod runtime;
pub mod scenario;
pub mod sim;
pub mod storage;
pub mod trace;
pub mod util;
pub mod workflow;

pub use error::{Error, Result};
