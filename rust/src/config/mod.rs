//! Configuration: experiment / simulation / job / policy parameters with a
//! TOML-lite parser (`key = value` lines + `[section]` headers — the
//! offline crate cache has no serde/toml).

use crate::error::{Error, Result};
use crate::net::detector::DetectorSpec;
use crate::net::faults::FaultSpec;
use crate::policy::reliability::ReliabilitySpec;
use std::collections::BTreeMap;

/// Churn specification (resolved to a `ChurnModel` by the coordinator).
#[derive(Debug, Clone, PartialEq)]
pub enum ChurnSpec {
    /// Homogeneous exponential with this MTBF (seconds).
    Exponential { mtbf: f64 },
    /// Rate doubles every `double_time` seconds (Fig. 4 right).
    TimeVarying { mtbf0: f64, double_time: f64 },
    /// Weibull heavy-tail with mean/shape (ablations).
    HeavyTail { mean: f64, shape: f64 },
    /// Synthetic published trace.
    Trace { kind: String },
}

impl Default for ChurnSpec {
    fn default() -> Self {
        ChurnSpec::Exponential { mtbf: 7200.0 }
    }
}

/// Checkpoint policy specification.
#[derive(Debug, Clone, PartialEq)]
pub enum PolicySpec {
    /// Fixed interval T seconds — the paper's baseline.
    Fixed { interval: f64 },
    /// The paper's adaptive scheme (estimated mu, V, T_d -> lambda*).
    Adaptive,
    /// Adaptive with the *true* failure rate (upper bound on achievable).
    Oracle,
    /// Never checkpoint (lower bound / sanity).
    Never,
}

impl Default for PolicySpec {
    fn default() -> Self {
        PolicySpec::Adaptive
    }
}

impl PolicySpec {
    pub fn name(&self) -> String {
        match self {
            PolicySpec::Fixed { interval } => format!("fixed({interval}s)"),
            PolicySpec::Adaptive => "adaptive".into(),
            PolicySpec::Oracle => "oracle".into(),
            PolicySpec::Never => "never".into(),
        }
    }
}

/// Full simulation configuration.
#[derive(Debug, Clone, PartialEq)]
pub struct SimConfig {
    /// Overlay population.
    pub n_peers: usize,
    /// RNG seed (trial index is mixed in separately).
    pub seed: u64,
    /// Stabilization period (seconds).
    pub stab_period: f64,
    /// Churn model.
    pub churn: ChurnSpec,
    /// Peers per job.
    pub k: usize,
    /// Fault-free job runtime (seconds).
    pub job_runtime: f64,
    /// Checkpoint overhead V (seconds). When `None` the full-stack sim
    /// derives it from image size / bandwidth; experiments reproducing the
    /// paper set it explicitly (20 s in Fig. 4).
    pub v: Option<f64>,
    /// Image download overhead T_d (seconds); `None` -> derived.
    pub td: Option<f64>,
    /// Checkpoint policy.
    pub policy: PolicySpec,
    /// Estimator window K (observations) for the Eq. 1 MLE.
    pub estimator_window: usize,
    /// Re-planning period for the adaptive policy (seconds).
    pub replan_period: f64,
    /// Hard wall-clock cap for one simulated job (seconds of sim time);
    /// guards against non-terminating configurations (U = 0 regimes).
    pub max_sim_time: f64,
    /// Failure-detection scheme (oracle = instantaneous, the seed
    /// behaviour; swim = probed, with latency and false positives).
    pub detector: DetectorSpec,
    /// Injected faults on the control/data planes (default: none).
    pub faults: FaultSpec,
    /// Per-peer reliability scoring (default: off — the seed behaviour,
    /// digest-bit-identical).
    pub reliability: ReliabilitySpec,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            n_peers: 512,
            seed: 42,
            stab_period: 30.0,
            churn: ChurnSpec::default(),
            k: 16,
            job_runtime: 4.0 * 3600.0,
            v: Some(20.0),
            td: Some(50.0),
            policy: PolicySpec::default(),
            estimator_window: 64,
            replan_period: 300.0,
            max_sim_time: 60.0 * 24.0 * 3600.0,
            detector: DetectorSpec::default(),
            faults: FaultSpec::default(),
            reliability: ReliabilitySpec::default(),
        }
    }
}

impl SimConfig {
    /// Validate invariants; returns self for chaining.
    pub fn validated(self) -> Result<Self> {
        if self.k == 0 || self.k > self.n_peers {
            return Err(Error::Config(format!(
                "k={} must be in 1..=n_peers={}",
                self.k, self.n_peers
            )));
        }
        if self.job_runtime <= 0.0 {
            return Err(Error::Config("job_runtime must be positive".into()));
        }
        if let Some(v) = self.v {
            if v < 0.0 {
                return Err(Error::Config("v must be >= 0".into()));
            }
        }
        if self.stab_period <= 0.0 {
            return Err(Error::Config("stab_period must be positive".into()));
        }
        if self.estimator_window == 0 {
            return Err(Error::Config("estimator_window must be >= 1".into()));
        }
        self.detector.validated()?;
        self.faults.validated()?;
        self.reliability.validated()?;
        Ok(self)
    }

    /// Parse from TOML-lite text (see module docs). Unknown keys error —
    /// typos in experiment configs must not silently default.
    pub fn from_toml_lite(text: &str) -> Result<Self> {
        let kv = parse_toml_lite(text)?;
        let mut cfg = SimConfig::default();
        for (key, val) in &kv {
            match key.as_str() {
                "sim.n_peers" => cfg.n_peers = parse_num(key, val)? as usize,
                "sim.seed" => cfg.seed = parse_num(key, val)? as u64,
                "sim.stab_period" => cfg.stab_period = parse_num(key, val)?,
                "sim.max_sim_time" => cfg.max_sim_time = parse_num(key, val)?,
                "churn.model" => {
                    cfg.churn = match val.as_str() {
                        "exponential" => {
                            ChurnSpec::Exponential { mtbf: get_num(&kv, "churn.mtbf", 7200.0) }
                        }
                        "time_varying" => ChurnSpec::TimeVarying {
                            mtbf0: get_num(&kv, "churn.mtbf", 7200.0),
                            double_time: get_num(&kv, "churn.double_time", 72_000.0),
                        },
                        "heavy_tail" => ChurnSpec::HeavyTail {
                            mean: get_num(&kv, "churn.mean", 7200.0),
                            shape: get_num(&kv, "churn.shape", 0.7),
                        },
                        "trace" => ChurnSpec::Trace {
                            kind: kv
                                .get("churn.kind")
                                .cloned()
                                .unwrap_or_else(|| "gnutella".into()),
                        },
                        other => {
                            return Err(Error::Config(format!("unknown churn.model '{other}'")))
                        }
                    }
                }
                "churn.mtbf" | "churn.double_time" | "churn.mean" | "churn.shape"
                | "churn.kind" => {} // consumed above
                "job.k" => cfg.k = parse_num(key, val)? as usize,
                "job.runtime" => cfg.job_runtime = parse_num(key, val)?,
                "job.v" => cfg.v = Some(parse_num(key, val)?),
                "job.td" => cfg.td = Some(parse_num(key, val)?),
                "policy.kind" => {
                    cfg.policy = match val.as_str() {
                        "fixed" => PolicySpec::Fixed {
                            interval: get_num(&kv, "policy.interval", 300.0),
                        },
                        "adaptive" => PolicySpec::Adaptive,
                        "oracle" => PolicySpec::Oracle,
                        "never" => PolicySpec::Never,
                        other => {
                            return Err(Error::Config(format!("unknown policy.kind '{other}'")))
                        }
                    }
                }
                "policy.interval" => {} // consumed above
                "estimator.window" => cfg.estimator_window = parse_num(key, val)? as usize,
                "estimator.replan_period" => cfg.replan_period = parse_num(key, val)?,
                "detector.key" => cfg.detector = DetectorSpec::parse(val)?,
                "faults.key" => cfg.faults = FaultSpec::parse(val)?,
                "reliability.key" => cfg.reliability = ReliabilitySpec::parse(val)?,
                other => return Err(Error::Config(format!("unknown config key '{other}'"))),
            }
        }
        cfg.validated()
    }
}

/// Parse `[section]` + `key = value` lines into `section.key -> value`.
fn parse_toml_lite(text: &str) -> Result<BTreeMap<String, String>> {
    let mut out = BTreeMap::new();
    let mut section = String::new();
    for (i, raw) in text.lines().enumerate() {
        let line = raw.split('#').next().unwrap_or("").trim();
        if line.is_empty() {
            continue;
        }
        if line.starts_with('[') && line.ends_with(']') {
            section = line[1..line.len() - 1].trim().to_string();
            continue;
        }
        let Some((k, v)) = line.split_once('=') else {
            return Err(Error::Config(format!("line {}: expected key = value", i + 1)));
        };
        let key = if section.is_empty() {
            k.trim().to_string()
        } else {
            format!("{section}.{}", k.trim())
        };
        out.insert(key, v.trim().trim_matches('"').to_string());
    }
    Ok(out)
}

fn parse_num(key: &str, val: &str) -> Result<f64> {
    val.parse::<f64>()
        .map_err(|_| Error::Config(format!("key '{key}': '{val}' is not a number")))
}

fn get_num(kv: &BTreeMap<String, String>, key: &str, default: f64) -> f64 {
    kv.get(key).and_then(|v| v.parse().ok()).unwrap_or(default)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_is_valid() {
        SimConfig::default().validated().unwrap();
    }

    #[test]
    fn parses_full_config() {
        let text = r#"
            # Fig 4-left style setup
            [sim]
            n_peers = 512
            seed = 7
            [churn]
            model = "time_varying"
            mtbf = 7200
            double_time = 72000
            [job]
            k = 16
            runtime = 14400
            v = 20
            td = 50
            [policy]
            kind = "fixed"
            interval = 300
        "#;
        let cfg = SimConfig::from_toml_lite(text).unwrap();
        assert_eq!(cfg.n_peers, 512);
        assert_eq!(cfg.seed, 7);
        assert_eq!(
            cfg.churn,
            ChurnSpec::TimeVarying { mtbf0: 7200.0, double_time: 72_000.0 }
        );
        assert_eq!(cfg.policy, PolicySpec::Fixed { interval: 300.0 });
        assert_eq!(cfg.v, Some(20.0));
    }

    #[test]
    fn rejects_unknown_keys() {
        let e = SimConfig::from_toml_lite("[job]\nkk = 3\n").unwrap_err();
        assert!(e.to_string().contains("unknown config key"));
    }

    #[test]
    fn rejects_bad_values() {
        assert!(SimConfig::from_toml_lite("[job]\nk = banana\n").is_err());
        assert!(SimConfig::from_toml_lite("[policy]\nkind = \"nope\"\n").is_err());
        assert!(SimConfig::from_toml_lite("[job]\nk = 0\n").is_err());
    }

    #[test]
    fn parses_detector_and_faults_keys() {
        let text = r#"
            [detector]
            key = "swim:10:30:3"
            [faults]
            key = "loss:0.05+partition:600:300:0.3"
        "#;
        let cfg = SimConfig::from_toml_lite(text).unwrap();
        assert_eq!(cfg.detector.key(), "swim:10:30:3");
        assert_eq!(cfg.faults.key(), "loss:0.05+partition:600:300:0.3");
        // Defaults stay the seed behaviour: oracle detection, no faults.
        assert_eq!(SimConfig::default().detector, DetectorSpec::Oracle);
        assert!(SimConfig::default().faults.is_none());
        // Out-of-range keys are rejected at validation time.
        assert!(SimConfig::from_toml_lite("[faults]\nkey = \"loss:1.5\"\n").is_err());
        assert!(SimConfig::from_toml_lite("[detector]\nkey = \"swim:0:30:3\"\n").is_err());
    }

    #[test]
    fn parses_reliability_key() {
        let cfg = SimConfig::from_toml_lite("[reliability]\nkey = \"window:32:0.9\"\n").unwrap();
        assert_eq!(cfg.reliability.key(), "window:32:0.9");
        // Default stays the seed behaviour: scoring off.
        assert_eq!(SimConfig::default().reliability, ReliabilitySpec::Off);
        assert!(SimConfig::from_toml_lite("[reliability]\nkey = \"window:0:0.9\"\n").is_err());
        assert!(SimConfig::from_toml_lite("[reliability]\nkey = \"window:16:1.5\"\n").is_err());
    }

    #[test]
    fn k_bounds_checked() {
        let mut cfg = SimConfig { k: 100, n_peers: 10, ..SimConfig::default() };
        assert!(cfg.clone().validated().is_err());
        cfg.k = 10;
        assert!(cfg.validated().is_ok());
    }

    #[test]
    fn policy_names() {
        assert_eq!(PolicySpec::Fixed { interval: 60.0 }.name(), "fixed(60s)");
        assert_eq!(PolicySpec::Adaptive.name(), "adaptive");
    }
}
