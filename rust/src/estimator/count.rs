//! Count-based estimator — the naive baseline: cumulative failures over
//! cumulative observed lifetime since the start (no window). Converges to
//! the true rate on stationary churn but never adapts afterwards — the
//! ablation shows exactly where that breaks (Fig. 4 right conditions).

use super::RateEstimator;

/// Cumulative failures / cumulative lifetime.
#[derive(Debug, Clone, Default)]
pub struct CountEstimator {
    n: u64,
    total: f64,
    min_obs: u64,
}

impl CountEstimator {
    pub fn new() -> Self {
        CountEstimator { n: 0, total: 0.0, min_obs: 8 }
    }

    pub fn with_min_obs(mut self, min_obs: u64) -> Self {
        self.min_obs = min_obs.max(1);
        self
    }
}

impl RateEstimator for CountEstimator {
    fn observe(&mut self, lifetime: f64) {
        self.n += 1;
        self.total += lifetime.max(1e-6);
    }

    fn rate(&self) -> Option<f64> {
        if self.n < self.min_obs || self.total <= 0.0 {
            None
        } else {
            Some(self.n as f64 / self.total)
        }
    }

    fn reset(&mut self) {
        self.n = 0;
        self.total = 0.0;
    }

    fn n_observed(&self) -> u64 {
        self.n
    }

    fn name(&self) -> &'static str {
        "count"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn equals_mle_without_window() {
        let mut e = CountEstimator::new();
        for _ in 0..100 {
            e.observe(100.0);
        }
        assert!((e.rate().unwrap() - 0.01).abs() < 1e-12);
    }

    #[test]
    fn sluggish_after_rate_change() {
        let mut rng = Pcg64::new(30, 0);
        let mut e = CountEstimator::new();
        for _ in 0..1000 {
            e.observe(rng.exp(1e-3));
        }
        for _ in 0..100 {
            e.observe(rng.exp(4e-3));
        }
        // True current rate 4e-3, but the unwindowed estimate barely moved.
        let got = e.rate().unwrap();
        assert!(got < 2e-3, "unwindowed estimator should lag, got {got}");
    }
}
