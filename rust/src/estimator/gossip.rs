//! Section 3.1.4 — global vs local estimation.
//!
//! Every peer periodically piggybacks its most recent local estimates of
//! (μ, V, T_d) onto the computation messages it already sends; receivers
//! fold the values into a decayed average. No extra messages — only a few
//! bytes on existing ones — and the coordinated checkpoint rate stops
//! being hostage to the single most pessimistic local μ estimate.

use crate::net::overlay::PeerId;

/// One peer's piggybacked estimate triple.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Piggyback {
    pub from: PeerId,
    pub mu: f64,
    pub v: f64,
    pub td: f64,
}

/// Per-peer aggregation state: keeps the freshest sample from each source
/// (bounded) and serves the global average.
#[derive(Debug, Clone)]
pub struct GossipAggregator {
    /// (source, sample, received_at). Bounded ring by `capacity`.
    samples: Vec<(PeerId, Piggyback, f64)>,
    capacity: usize,
    /// Samples older than this (seconds) are ignored in the average.
    pub freshness: f64,
}

impl GossipAggregator {
    pub fn new(capacity: usize, freshness: f64) -> Self {
        assert!(capacity > 0 && freshness > 0.0);
        GossipAggregator { samples: Vec::with_capacity(capacity), capacity, freshness }
    }

    /// Fold in a piggybacked sample received at time `now`.
    pub fn receive(&mut self, pb: Piggyback, now: f64) {
        if let Some(slot) = self.samples.iter_mut().find(|(src, _, _)| *src == pb.from) {
            *slot = (pb.from, pb, now);
            return;
        }
        if self.samples.len() == self.capacity {
            // Evict the stalest entry.
            let (idx, _) = self
                .samples
                .iter()
                .enumerate()
                .min_by(|a, b| a.1 .2.partial_cmp(&b.1 .2).unwrap())
                .unwrap();
            self.samples.swap_remove(idx);
        }
        self.samples.push((pb.from, pb, now));
    }

    /// Global averages over fresh samples, *including* the local estimate
    /// passed in (the local peer always counts). Returns (mu, v, td).
    pub fn global(&self, local: Piggyback, now: f64) -> (f64, f64, f64) {
        let mut n = 1.0;
        let (mut mu, mut v, mut td) = (local.mu, local.v, local.td);
        for &(src, pb, at) in &self.samples {
            if src == local.from || now - at > self.freshness {
                continue;
            }
            mu += pb.mu;
            v += pb.v;
            td += pb.td;
            n += 1.0;
        }
        (mu / n, v / n, td / n)
    }

    /// How many fresh remote samples contribute right now.
    pub fn fresh_count(&self, now: f64) -> usize {
        self.samples.iter().filter(|(_, _, at)| now - at <= self.freshness).count()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pb(from: PeerId, mu: f64) -> Piggyback {
        Piggyback { from, mu, v: 20.0, td: 50.0 }
    }

    #[test]
    fn averages_fresh_samples() {
        let mut g = GossipAggregator::new(8, 600.0);
        g.receive(pb(1, 2e-4), 10.0);
        g.receive(pb(2, 4e-4), 20.0);
        let (mu, v, td) = g.global(pb(0, 3e-4), 30.0);
        assert!((mu - 3e-4).abs() < 1e-12);
        assert!((v - 20.0).abs() < 1e-12);
        assert!((td - 50.0).abs() < 1e-12);
    }

    #[test]
    fn stale_samples_ignored() {
        let mut g = GossipAggregator::new(8, 100.0);
        g.receive(pb(1, 100.0), 0.0);
        let (mu, _, _) = g.global(pb(0, 2.0), 500.0);
        assert!((mu - 2.0).abs() < 1e-12);
        assert_eq!(g.fresh_count(500.0), 0);
    }

    #[test]
    fn newer_sample_replaces_same_source() {
        let mut g = GossipAggregator::new(8, 600.0);
        g.receive(pb(1, 1.0), 0.0);
        g.receive(pb(1, 5.0), 10.0);
        let (mu, _, _) = g.global(pb(0, 5.0), 20.0);
        assert!((mu - 5.0).abs() < 1e-12);
    }

    #[test]
    fn capacity_evicts_stalest() {
        let mut g = GossipAggregator::new(2, 1e9);
        g.receive(pb(1, 1.0), 0.0);
        g.receive(pb(2, 2.0), 10.0);
        g.receive(pb(3, 3.0), 20.0); // evicts source 1
        let (mu, _, _) = g.global(pb(0, 2.5), 30.0);
        assert!((mu - (2.5 + 2.0 + 3.0) / 3.0).abs() < 1e-12);
    }

    #[test]
    fn local_never_double_counted() {
        let mut g = GossipAggregator::new(8, 600.0);
        g.receive(pb(0, 100.0), 0.0); // our own echo
        let (mu, _, _) = g.global(pb(0, 2.0), 1.0);
        assert!((mu - 2.0).abs() < 1e-12);
    }

    #[test]
    fn global_tighter_than_local() {
        // Averaging k noisy local estimates cuts the spread ~ sqrt(k):
        // the Section 3.1.4 motivation, checked end-to-end.
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(40, 0);
        let true_mu = 1.0 / 7200.0;
        let noisy = |rng: &mut Pcg64| true_mu * (1.0 + 0.15 * rng.gaussian());
        let mut local_errs = 0.0;
        let mut global_errs = 0.0;
        let trials = 500;
        for _ in 0..trials {
            let mut g = GossipAggregator::new(16, 600.0);
            for src in 1..=9 {
                g.receive(Piggyback { from: src, mu: noisy(&mut rng), v: 20.0, td: 50.0 }, 0.0);
            }
            let local = Piggyback { from: 0, mu: noisy(&mut rng), v: 20.0, td: 50.0 };
            let (gmu, _, _) = g.global(local, 1.0);
            local_errs += (local.mu - true_mu).abs();
            global_errs += (gmu - true_mu).abs();
        }
        assert!(
            global_errs < local_errs * 0.5,
            "global {global_errs} vs local {local_errs}"
        );
    }
}
