//! Section 3.1.4 — global vs local estimation.
//!
//! Every peer periodically piggybacks its most recent local estimates of
//! (μ, V, T_d) onto the computation messages it already sends; receivers
//! fold the values into a decayed average. No extra messages — only a few
//! bytes on existing ones — and the coordinated checkpoint rate stops
//! being hostage to the single most pessimistic local μ estimate.

use super::{mle::MleEstimator, RateEstimator};
use crate::net::overlay::PeerId;

/// One peer's piggybacked estimate triple.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct Piggyback {
    pub from: PeerId,
    pub mu: f64,
    pub v: f64,
    pub td: f64,
}

/// Per-peer aggregation state: keeps the freshest sample from each source
/// (bounded) and serves the global average.
#[derive(Debug, Clone)]
pub struct GossipAggregator {
    /// (source, sample, received_at). Bounded ring by `capacity`.
    samples: Vec<(PeerId, Piggyback, f64)>,
    capacity: usize,
    /// Samples older than this (seconds) are ignored in the average.
    pub freshness: f64,
}

impl GossipAggregator {
    pub fn new(capacity: usize, freshness: f64) -> Self {
        assert!(capacity > 0 && freshness > 0.0);
        GossipAggregator { samples: Vec::with_capacity(capacity), capacity, freshness }
    }

    /// Fold in a piggybacked sample received at time `now`.
    pub fn receive(&mut self, pb: Piggyback, now: f64) {
        if let Some(slot) = self.samples.iter_mut().find(|(src, _, _)| *src == pb.from) {
            *slot = (pb.from, pb, now);
            return;
        }
        if self.samples.len() == self.capacity {
            // Evict the stalest entry.
            let (idx, _) = self
                .samples
                .iter()
                .enumerate()
                .min_by(|a, b| a.1 .2.partial_cmp(&b.1 .2).unwrap())
                .unwrap();
            self.samples.swap_remove(idx);
        }
        self.samples.push((pb.from, pb, now));
    }

    /// Global averages over fresh samples, *including* the local estimate
    /// passed in (the local peer always counts). Returns (mu, v, td).
    pub fn global(&self, local: Piggyback, now: f64) -> (f64, f64, f64) {
        let mut n = 1.0;
        let (mut mu, mut v, mut td) = (local.mu, local.v, local.td);
        for &(src, pb, at) in &self.samples {
            if src == local.from || now - at > self.freshness {
                continue;
            }
            mu += pb.mu;
            v += pb.v;
            td += pb.td;
            n += 1.0;
        }
        (mu / n, v / n, td / n)
    }

    /// How many fresh remote samples contribute right now.
    pub fn fresh_count(&self, now: f64) -> usize {
        self.samples.iter().filter(|(_, _, at)| now - at <= self.freshness).count()
    }
}

/// Samples never age out inside the estimator — observation count stands
/// in for time, and every local view re-publishes on each new lifetime.
const NEVER_STALE: f64 = f64::MAX;

/// [`RateEstimator`] over the Section 3.1.4 scheme: `fanout` independent
/// local Eq. 1 MLE views fed round-robin (standing in for the distinct
/// peers a member hears from), each piggybacking its estimate into a
/// [`GossipAggregator`] whose global average is the reported rate. On
/// homogeneous churn this reproduces the single-MLE answer; on noisy
/// churn the averaging tightens the estimate ~√fanout (see
/// `global_tighter_than_local`).
#[derive(Debug, Clone)]
pub struct GossipEstimator {
    locals: Vec<MleEstimator>,
    agg: GossipAggregator,
    next: usize,
    n: u64,
}

impl GossipEstimator {
    /// `fanout` local views sharing the scenario's window K between them
    /// (each holds `max(K / fanout, 1)` lifetimes).
    pub fn new(fanout: usize, window: usize) -> Self {
        assert!(fanout >= 1);
        let per = (window / fanout).max(1);
        GossipEstimator {
            locals: (0..fanout).map(|_| MleEstimator::new(per)).collect(),
            agg: GossipAggregator::new(fanout, NEVER_STALE),
            next: 0,
            n: 0,
        }
    }
}

impl RateEstimator for GossipEstimator {
    fn observe(&mut self, lifetime: f64) {
        let i = self.next;
        self.next = (self.next + 1) % self.locals.len();
        self.n += 1;
        self.locals[i].observe(lifetime);
        if let Some(mu) = self.locals[i].rate() {
            self.agg
                .receive(Piggyback { from: i, mu, v: 0.0, td: 0.0 }, self.n as f64);
        }
    }

    fn rate(&self) -> Option<f64> {
        // First warm local view is "us"; the aggregator skips its own
        // piggybacked echo, so each warm view counts exactly once.
        let (from, mu) = self
            .locals
            .iter()
            .enumerate()
            .find_map(|(i, l)| l.rate().map(|mu| (i, mu)))?;
        Some(
            self.agg
                .global(Piggyback { from, mu, v: 0.0, td: 0.0 }, self.n as f64)
                .0,
        )
    }

    fn reset(&mut self) {
        for l in &mut self.locals {
            l.reset();
        }
        self.agg = GossipAggregator::new(self.locals.len(), NEVER_STALE);
        self.next = 0;
        self.n = 0;
    }

    fn n_observed(&self) -> u64 {
        self.n
    }

    fn name(&self) -> &'static str {
        "gossip"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn pb(from: PeerId, mu: f64) -> Piggyback {
        Piggyback { from, mu, v: 20.0, td: 50.0 }
    }

    #[test]
    fn averages_fresh_samples() {
        let mut g = GossipAggregator::new(8, 600.0);
        g.receive(pb(1, 2e-4), 10.0);
        g.receive(pb(2, 4e-4), 20.0);
        let (mu, v, td) = g.global(pb(0, 3e-4), 30.0);
        assert!((mu - 3e-4).abs() < 1e-12);
        assert!((v - 20.0).abs() < 1e-12);
        assert!((td - 50.0).abs() < 1e-12);
    }

    #[test]
    fn stale_samples_ignored() {
        let mut g = GossipAggregator::new(8, 100.0);
        g.receive(pb(1, 100.0), 0.0);
        let (mu, _, _) = g.global(pb(0, 2.0), 500.0);
        assert!((mu - 2.0).abs() < 1e-12);
        assert_eq!(g.fresh_count(500.0), 0);
    }

    #[test]
    fn newer_sample_replaces_same_source() {
        let mut g = GossipAggregator::new(8, 600.0);
        g.receive(pb(1, 1.0), 0.0);
        g.receive(pb(1, 5.0), 10.0);
        let (mu, _, _) = g.global(pb(0, 5.0), 20.0);
        assert!((mu - 5.0).abs() < 1e-12);
    }

    #[test]
    fn capacity_evicts_stalest() {
        let mut g = GossipAggregator::new(2, 1e9);
        g.receive(pb(1, 1.0), 0.0);
        g.receive(pb(2, 2.0), 10.0);
        g.receive(pb(3, 3.0), 20.0); // evicts source 1
        let (mu, _, _) = g.global(pb(0, 2.5), 30.0);
        assert!((mu - (2.5 + 2.0 + 3.0) / 3.0).abs() < 1e-12);
    }

    #[test]
    fn local_never_double_counted() {
        let mut g = GossipAggregator::new(8, 600.0);
        g.receive(pb(0, 100.0), 0.0); // our own echo
        let (mu, _, _) = g.global(pb(0, 2.0), 1.0);
        assert!((mu - 2.0).abs() < 1e-12);
    }

    #[test]
    fn gossip_estimator_averages_local_views() {
        let mut e = GossipEstimator::new(4, 32);
        for _ in 0..32 {
            e.observe(500.0);
        }
        // Each of the 4 views holds 8 lifetimes of 500 s; the global
        // average is exactly the MLE answer.
        assert!((e.rate().unwrap() - 1.0 / 500.0).abs() < 1e-12);
        assert_eq!(e.n_observed(), 32);
        assert_eq!(e.name(), "gossip");
    }

    #[test]
    fn gossip_estimator_cold_until_one_view_is_warm() {
        // fanout 2, window 32 -> 16 per view, min_obs 8: view 0 sees its
        // 8th lifetime on the 15th observation overall.
        let mut e = GossipEstimator::new(2, 32);
        for _ in 0..14 {
            e.observe(100.0);
            assert!(e.rate().is_none());
        }
        e.observe(100.0);
        assert!(e.rate().is_some());
    }

    #[test]
    fn global_tighter_than_local() {
        // Averaging k noisy local estimates cuts the spread ~ sqrt(k):
        // the Section 3.1.4 motivation, checked end-to-end.
        use crate::util::rng::Pcg64;
        let mut rng = Pcg64::new(40, 0);
        let true_mu = 1.0 / 7200.0;
        let noisy = |rng: &mut Pcg64| true_mu * (1.0 + 0.15 * rng.gaussian());
        let mut local_errs = 0.0;
        let mut global_errs = 0.0;
        let trials = 500;
        for _ in 0..trials {
            let mut g = GossipAggregator::new(16, 600.0);
            for src in 1..=9 {
                g.receive(Piggyback { from: src, mu: noisy(&mut rng), v: 20.0, td: 50.0 }, 0.0);
            }
            let local = Piggyback { from: 0, mu: noisy(&mut rng), v: 20.0, td: 50.0 };
            let (gmu, _, _) = g.global(local, 1.0);
            local_errs += (local.mu - true_mu).abs();
            global_errs += (gmu - true_mu).abs();
        }
        assert!(
            global_errs < local_errs * 0.5,
            "global {global_errs} vs local {local_errs}"
        );
    }
}
