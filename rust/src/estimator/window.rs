//! Time-windowed rate estimator — comparison baseline: failures observed
//! per unit of *watched peer-time* in the last `horizon` seconds.
//!
//! Unlike the MLE over completed lifetimes, this is an exposure-based
//! (actuarial) estimator: robust to censoring but needs explicit exposure
//! bookkeeping from the failure detector.

use super::RateEstimator;
use std::collections::VecDeque;

/// Failures / exposure over a sliding time horizon.
#[derive(Debug, Clone)]
pub struct TimeWindowEstimator {
    horizon: f64,
    /// (time, lifetime) of observed failures.
    failures: VecDeque<(f64, f64)>,
    /// (time, peer_seconds) exposure records.
    exposure: VecDeque<(f64, f64)>,
    now: f64,
    n: u64,
}

impl TimeWindowEstimator {
    pub fn new(horizon: f64) -> Self {
        assert!(horizon > 0.0);
        TimeWindowEstimator {
            horizon,
            failures: VecDeque::new(),
            exposure: VecDeque::new(),
            now: 0.0,
            n: 0,
        }
    }

    /// Record watched peer-seconds (call from each stabilization tick).
    pub fn add_exposure(&mut self, now: f64, peer_seconds: f64) {
        self.now = self.now.max(now);
        self.exposure.push_back((now, peer_seconds));
        self.evict();
    }

    fn evict(&mut self) {
        let cut = self.now - self.horizon;
        while self.failures.front().is_some_and(|&(t, _)| t < cut) {
            self.failures.pop_front();
        }
        while self.exposure.front().is_some_and(|&(t, _)| t < cut) {
            self.exposure.pop_front();
        }
    }
}

impl RateEstimator for TimeWindowEstimator {
    fn observe(&mut self, lifetime: f64) {
        // Interpreted as: a failure observed "now" with this lifetime.
        self.failures.push_back((self.now, lifetime));
        self.n += 1;
        self.evict();
    }

    fn rate(&self) -> Option<f64> {
        let expo: f64 = self.exposure.iter().map(|&(_, e)| e).sum();
        if expo <= 0.0 || self.failures.len() < 2 {
            return None;
        }
        Some(self.failures.len() as f64 / expo)
    }

    fn reset(&mut self) {
        self.failures.clear();
        self.exposure.clear();
        self.now = 0.0;
        self.n = 0;
    }

    fn n_observed(&self) -> u64 {
        self.n
    }

    fn name(&self) -> &'static str {
        "time_window"
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rate_is_failures_over_exposure() {
        let mut e = TimeWindowEstimator::new(1000.0);
        e.add_exposure(100.0, 500.0);
        e.observe(50.0);
        e.observe(70.0);
        // 2 failures / 500 peer-seconds
        assert!((e.rate().unwrap() - 2.0 / 500.0).abs() < 1e-12);
    }

    #[test]
    fn old_data_evicted() {
        let mut e = TimeWindowEstimator::new(100.0);
        e.add_exposure(0.0, 1000.0);
        e.observe(10.0);
        e.observe(10.0);
        assert!(e.rate().is_some());
        // Much later: old failures and exposure are both gone.
        e.add_exposure(1000.0, 50.0);
        assert!(e.rate().is_none());
    }

    #[test]
    fn needs_some_failures() {
        let mut e = TimeWindowEstimator::new(100.0);
        e.add_exposure(0.0, 100.0);
        assert!(e.rate().is_none());
    }
}
