//! Eq. (1): μ̂ = K / Σ tᵢ over the most recent K observed lifetimes.
//!
//! The paper's chosen estimator — Maximum Likelihood for exponential
//! lifetimes, windowed so it tracks non-stationary rates (Fig. 4 right).

use super::RateEstimator;
use std::collections::VecDeque;

/// Windowed MLE failure-rate estimator.
#[derive(Debug, Clone)]
pub struct MleEstimator {
    window: VecDeque<f64>,
    capacity: usize,
    /// Minimum observations before reporting a rate.
    min_obs: usize,
    sum: f64,
    total_seen: u64,
}

impl MleEstimator {
    /// `capacity` = K in Eq. 1. `min_obs` defaults to min(8, K).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        MleEstimator {
            window: VecDeque::with_capacity(capacity),
            capacity,
            min_obs: capacity.min(8),
            sum: 0.0,
            total_seen: 0,
        }
    }

    pub fn with_min_obs(mut self, min_obs: usize) -> Self {
        self.min_obs = min_obs.max(1);
        self
    }

    /// Current window contents (for the planner artifact's [B, W] input).
    pub fn window(&self) -> impl Iterator<Item = f64> + '_ {
        self.window.iter().copied()
    }

    pub fn window_len(&self) -> usize {
        self.window.len()
    }
}

impl RateEstimator for MleEstimator {
    fn observe(&mut self, lifetime: f64) {
        let lifetime = lifetime.max(1e-6); // zero-length sessions: clamp
        if self.window.len() == self.capacity {
            if let Some(old) = self.window.pop_front() {
                self.sum -= old;
            }
        }
        self.window.push_back(lifetime);
        self.sum += lifetime;
        self.total_seen += 1;
        // Periodic exact re-sum to stop FP drift in very long runs.
        if self.total_seen % 4096 == 0 {
            self.sum = self.window.iter().sum();
        }
    }

    fn rate(&self) -> Option<f64> {
        if self.window.len() < self.min_obs || self.sum <= 0.0 {
            None
        } else {
            Some(self.window.len() as f64 / self.sum)
        }
    }

    fn n_observed(&self) -> u64 {
        self.total_seen
    }

    fn name(&self) -> &'static str {
        "mle"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn exact_on_constant_lifetimes() {
        let mut e = MleEstimator::new(16);
        for _ in 0..16 {
            e.observe(100.0);
        }
        assert!((e.rate().unwrap() - 0.01).abs() < 1e-12);
    }

    #[test]
    fn needs_min_observations() {
        let mut e = MleEstimator::new(64);
        for _ in 0..7 {
            e.observe(100.0);
            assert!(e.rate().is_none());
        }
        e.observe(100.0);
        assert!(e.rate().is_some());
    }

    #[test]
    fn converges_on_exponential_data() {
        let mut rng = Pcg64::new(14, 0);
        let mut e = MleEstimator::new(256);
        let true_rate = 1.0 / 7200.0;
        for _ in 0..256 {
            e.observe(rng.exp(true_rate));
        }
        let got = e.rate().unwrap();
        // K=256 -> stderr ~ rate/sqrt(K) ~ 6%; allow 3 sigma.
        assert!(
            (got - true_rate).abs() < true_rate * 0.2,
            "got {got} want {true_rate}"
        );
    }

    #[test]
    fn window_slides_tracking_rate_change() {
        let mut e = MleEstimator::new(32);
        for _ in 0..32 {
            e.observe(1000.0);
        }
        let before = e.rate().unwrap();
        for _ in 0..32 {
            e.observe(250.0); // rate quadruples
        }
        let after = e.rate().unwrap();
        assert!((after / before - 4.0).abs() < 1e-9);
    }

    #[test]
    fn estimation_error_10_to_15_pct_at_paper_window() {
        // The paper quotes 10-15% typical estimation error; with K=64 the
        // MLE's relative stderr is 1/sqrt(64) = 12.5%. Verify empirically.
        let mut rng = Pcg64::new(15, 0);
        let true_rate = 1.0 / 7200.0;
        let mut errs = Vec::new();
        for _ in 0..500 {
            let mut e = MleEstimator::new(64);
            for _ in 0..64 {
                e.observe(rng.exp(true_rate));
            }
            errs.push((e.rate().unwrap() - true_rate).abs() / true_rate);
        }
        let mean_err = errs.iter().sum::<f64>() / errs.len() as f64;
        assert!(
            (0.06..0.20).contains(&mean_err),
            "mean relative error {mean_err}, expected ~0.10"
        );
    }

    #[test]
    fn zero_lifetime_clamped() {
        let mut e = MleEstimator::new(4).with_min_obs(1);
        e.observe(0.0);
        assert!(e.rate().unwrap().is_finite());
    }
}
