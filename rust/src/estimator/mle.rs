//! Eq. (1): μ̂ = K / Σ tᵢ over the most recent K observed lifetimes.
//!
//! The paper's chosen estimator — Maximum Likelihood for exponential
//! lifetimes, windowed so it tracks non-stationary rates (Fig. 4 right).
//!
//! The window lives in a compacting `Vec` rather than a `VecDeque`: the
//! buffer appends until it reaches `2K` entries, then memmoves the live
//! half back to the front. Amortized O(1) per observation with a running
//! sum, and — the point — the window is always one contiguous
//! chronological slice, so `PolicyCtx::lifetimes` can borrow it directly
//! instead of cloning a `Vec<f64>` on every decide/replan. The running-sum
//! update applies the exact FP operation order of the historical deque
//! implementation (evict, push, add), keeping rates bit-identical across
//! the representation change.

use super::RateEstimator;

/// Windowed MLE failure-rate estimator.
#[derive(Debug, Clone)]
pub struct MleEstimator {
    /// Append-only buffer, compacted at `2 * capacity`; the window is the
    /// trailing `min(len, capacity)` elements.
    buf: Vec<f64>,
    capacity: usize,
    /// Minimum observations before reporting a rate.
    min_obs: usize,
    sum: f64,
    total_seen: u64,
}

impl MleEstimator {
    /// `capacity` = K in Eq. 1. `min_obs` defaults to min(8, K).
    pub fn new(capacity: usize) -> Self {
        assert!(capacity > 0);
        MleEstimator {
            buf: Vec::with_capacity(2 * capacity),
            capacity,
            min_obs: capacity.min(8),
            sum: 0.0,
            total_seen: 0,
        }
    }

    pub fn with_min_obs(mut self, min_obs: usize) -> Self {
        self.min_obs = min_obs.max(1);
        self
    }

    /// The current window as one contiguous chronological slice (oldest
    /// first) — zero-copy input for the planner's `[B, W]` artifact and
    /// `PolicyCtx::lifetimes`.
    pub fn window_slice(&self) -> &[f64] {
        &self.buf[self.buf.len().saturating_sub(self.capacity)..]
    }

    /// Current window contents (for the planner artifact's [B, W] input).
    pub fn window(&self) -> impl Iterator<Item = f64> + '_ {
        self.window_slice().iter().copied()
    }

    pub fn window_len(&self) -> usize {
        self.buf.len().min(self.capacity)
    }
}

impl RateEstimator for MleEstimator {
    fn observe(&mut self, lifetime: f64) {
        let lifetime = lifetime.max(1e-6); // zero-length sessions: clamp
        // Evict the element sliding out of the window from the running sum
        // *before* adding the new one (the historical FP order).
        let start = self.buf.len().saturating_sub(self.capacity);
        if self.buf.len() - start == self.capacity {
            self.sum -= self.buf[start];
        }
        self.buf.push(lifetime);
        self.sum += lifetime;
        self.total_seen += 1;
        // Periodic exact re-sum to stop FP drift in very long runs.
        if self.total_seen % 4096 == 0 {
            self.sum = self.window_slice().iter().sum();
        }
        // Compact: memmove the live window back to the buffer front.
        if self.buf.len() == 2 * self.capacity {
            let cap = self.capacity;
            self.buf.copy_within(cap.., 0);
            self.buf.truncate(cap);
        }
    }

    fn rate(&self) -> Option<f64> {
        let n = self.window_len();
        if n < self.min_obs || self.sum <= 0.0 {
            None
        } else {
            Some(n as f64 / self.sum)
        }
    }

    fn reset(&mut self) {
        self.buf.clear();
        self.sum = 0.0;
        self.total_seen = 0;
    }

    fn n_observed(&self) -> u64 {
        self.total_seen
    }

    fn name(&self) -> &'static str {
        "mle"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn exact_on_constant_lifetimes() {
        let mut e = MleEstimator::new(16);
        for _ in 0..16 {
            e.observe(100.0);
        }
        assert!((e.rate().unwrap() - 0.01).abs() < 1e-12);
    }

    #[test]
    fn needs_min_observations() {
        let mut e = MleEstimator::new(64);
        for _ in 0..7 {
            e.observe(100.0);
            assert!(e.rate().is_none());
        }
        e.observe(100.0);
        assert!(e.rate().is_some());
    }

    #[test]
    fn converges_on_exponential_data() {
        let mut rng = Pcg64::new(14, 0);
        let mut e = MleEstimator::new(256);
        let true_rate = 1.0 / 7200.0;
        for _ in 0..256 {
            e.observe(rng.exp(true_rate));
        }
        let got = e.rate().unwrap();
        // K=256 -> stderr ~ rate/sqrt(K) ~ 6%; allow 3 sigma.
        assert!(
            (got - true_rate).abs() < true_rate * 0.2,
            "got {got} want {true_rate}"
        );
    }

    #[test]
    fn window_slides_tracking_rate_change() {
        let mut e = MleEstimator::new(32);
        for _ in 0..32 {
            e.observe(1000.0);
        }
        let before = e.rate().unwrap();
        for _ in 0..32 {
            e.observe(250.0); // rate quadruples
        }
        let after = e.rate().unwrap();
        assert!((after / before - 4.0).abs() < 1e-9);
    }

    #[test]
    fn window_slice_is_chronological_across_compactions() {
        // Push far past several 2K compaction points; the slice must
        // always be the last K observations in order, and the running sum
        // must match an exact recomputation.
        let mut e = MleEstimator::new(8);
        let mut fed = Vec::new();
        for i in 0..100u32 {
            let x = 10.0 + i as f64;
            e.observe(x);
            fed.push(x);
            let want: Vec<f64> =
                fed[fed.len().saturating_sub(8)..].to_vec();
            assert_eq!(e.window_slice(), &want[..], "after {} obs", i + 1);
            assert_eq!(e.window_len(), want.len());
            let exact: f64 = want.iter().sum();
            match e.rate() {
                Some(r) => {
                    assert!(want.len() >= 8);
                    assert!((r - want.len() as f64 / exact).abs() < 1e-9);
                }
                None => assert!(want.len() < 8),
            }
        }
    }

    #[test]
    fn reset_restores_fresh_state() {
        let mut a = MleEstimator::new(16);
        let mut b = MleEstimator::new(16);
        for i in 0..40 {
            a.observe(50.0 + i as f64);
        }
        a.reset();
        for e in [&mut a, &mut b] {
            for i in 0..20 {
                e.observe(100.0 + i as f64);
            }
        }
        assert_eq!(a.rate(), b.rate());
        assert_eq!(a.window_slice(), b.window_slice());
        assert_eq!(a.n_observed(), b.n_observed());
    }

    #[test]
    fn estimation_error_10_to_15_pct_at_paper_window() {
        // The paper quotes 10-15% typical estimation error; with K=64 the
        // MLE's relative stderr is 1/sqrt(64) = 12.5%. Verify empirically.
        let mut rng = Pcg64::new(15, 0);
        let true_rate = 1.0 / 7200.0;
        let mut errs = Vec::new();
        for _ in 0..500 {
            let mut e = MleEstimator::new(64);
            for _ in 0..64 {
                e.observe(rng.exp(true_rate));
            }
            errs.push((e.rate().unwrap() - true_rate).abs() / true_rate);
        }
        let mean_err = errs.iter().sum::<f64>() / errs.len() as f64;
        assert!(
            (0.06..0.20).contains(&mean_err),
            "mean relative error {mean_err}, expected ~0.10"
        );
    }

    #[test]
    fn zero_lifetime_clamped() {
        let mut e = MleEstimator::new(4).with_min_obs(1);
        e.observe(0.0);
        assert!(e.rate().unwrap().is_finite());
    }
}
