//! Online parameter estimation (paper Section 3.1).
//!
//! * [`mle`] — the Eq. 1 Maximum-Likelihood failure-rate estimator over a
//!   window of K observed lifetimes (the paper's choice, from its
//!   companion study \[15\]).
//! * [`ewma`], [`window`], [`count`] — the comparison estimators from that
//!   study, implemented for the ablation benches.
//! * [`gossip`] — Section 3.1.4's piggyback scheme: peers attach their
//!   local (μ, V, T_d) estimates to computation messages; receivers average
//!   them into a global view at zero extra message cost.
//! * [`overhead`] — Section 3.1.2/3.1.3: the Eq. 2 checkpoint-overhead
//!   calibration and the online T_d measurement.

pub mod categorized;
pub mod count;
pub mod ewma;
pub mod hybrid;
pub mod gossip;
pub mod mle;
pub mod overhead;
pub mod window;

/// Common interface: feed observed lifetimes, read the current rate.
pub trait RateEstimator: Send {
    /// Record one observed peer lifetime (seconds).
    fn observe(&mut self, lifetime: f64);

    /// Current estimate of the failure rate μ (per second), or `None`
    /// before enough observations have arrived.
    fn rate(&self) -> Option<f64>;

    /// Number of observations consumed.
    fn n_observed(&self) -> u64;

    /// Estimator name for reports.
    fn name(&self) -> &'static str;
}
