//! Online parameter estimation (paper Section 3.1).
//!
//! * [`mle`] — the Eq. 1 Maximum-Likelihood failure-rate estimator over a
//!   window of K observed lifetimes (the paper's choice, from its
//!   companion study \[15\]).
//! * [`ewma`], [`window`], [`count`] — the comparison estimators from that
//!   study, implemented for the ablation benches.
//! * [`gossip`] — Section 3.1.4's piggyback scheme: peers attach their
//!   local (μ, V, T_d) estimates to computation messages; receivers average
//!   them into a global view at zero extra message cost.
//! * [`overhead`] — Section 3.1.2/3.1.3: the Eq. 2 checkpoint-overhead
//!   calibration and the online T_d measurement.
//!
//! Estimators are pluggable through [`EstimatorSpec`] (resolved by the
//! [`crate::scenario`] registry): the coordinator paths consume the
//! [`WindowEstimator`] interface, which adds a lifetime-window view on top
//! of [`RateEstimator`] so any estimator can feed the planner's Eq. 1
//! input (`PolicyCtx::lifetimes`).

pub mod categorized;
pub mod count;
pub mod ewma;
pub mod hybrid;
pub mod gossip;
pub mod mle;
pub mod overhead;
pub mod window;

/// Common interface: feed observed lifetimes, read the current rate.
pub trait RateEstimator: Send {
    /// Record one observed peer lifetime (seconds).
    fn observe(&mut self, lifetime: f64);

    /// Current estimate of the failure rate μ (per second), or `None`
    /// before enough observations have arrived.
    fn rate(&self) -> Option<f64>;

    /// Restore the exact freshly-constructed state (drop all
    /// observations, keep configuration). Lets trial loops reuse one
    /// estimator allocation as scratch instead of re-boxing per run —
    /// `reset()` followed by N observes must be indistinguishable from a
    /// new estimator fed the same N observes.
    fn reset(&mut self);

    /// Number of observations consumed.
    fn n_observed(&self) -> u64;

    /// Estimator name for reports.
    fn name(&self) -> &'static str;
}

/// Which estimator a scenario runs. String keys for these live in
/// [`crate::scenario::registry`] (`"mle"`, `"ewma:0.1"`, …).
#[derive(Debug, Clone, PartialEq)]
pub enum EstimatorSpec {
    /// Eq. 1 windowed MLE — the paper's scheme. Window size K comes from
    /// the scenario's `estimator_window`.
    Mle,
    /// EWMA of observed lifetimes with smoothing factor `alpha`.
    Ewma { alpha: f64 },
    /// Cumulative failures / cumulative lifetime (naive, unwindowed).
    Count,
    /// §5 history+online hybrid: Gamma prior from a historical mean
    /// session length of `mean` seconds worth `confidence`
    /// pseudo-observations, over a windowed likelihood.
    Hybrid { mean: f64, confidence: f64 },
    /// Section 3.1.4 piggyback scheme: `fanout` local MLE views averaged
    /// through a [`gossip::GossipAggregator`] into a global estimate.
    Gossip { fanout: usize },
    /// Tian–Dai \[22\] category-stratified MLE: per-tertile windows whose
    /// pooled rate tracks mixed (heavy-tail) populations.
    Categorized,
}

impl Default for EstimatorSpec {
    fn default() -> Self {
        EstimatorSpec::Mle
    }
}

/// A rate estimator that can also render its evidence as a window of
/// lifetimes — the shape `PolicyCtx::lifetimes` and the planner artifact's
/// `[B, W]` input expect. Windowless estimators synthesize an equivalent
/// window from their point estimate (the Eq. 1 MLE over `n` copies of
/// `1/μ̂` recovers exactly `μ̂`).
pub trait WindowEstimator: Send {
    /// Record one observed peer lifetime (seconds).
    fn observe(&mut self, lifetime: f64);

    /// Current rate estimate, `None` before warm.
    fn rate(&self) -> Option<f64>;

    /// Lifetime window for the planner, borrowed zero-copy from the
    /// estimator's own storage (most recent last; empty = no estimate
    /// yet, policies fall back to their bootstrap interval). This is read
    /// on every decide/replan, so implementations keep it materialized
    /// rather than building a fresh `Vec` per call.
    fn lifetimes(&self) -> &[f64];

    /// Restore the exact freshly-constructed state (see
    /// [`RateEstimator::reset`]).
    fn reset(&mut self);

    /// Observations consumed.
    fn n_observed(&self) -> u64;

    /// Name for reports.
    fn name(&self) -> &'static str;
}

/// [`WindowEstimator`] over the Eq. 1 MLE: the window is the estimator's
/// actual observation window (byte-for-byte what the seed code fed the
/// planner).
pub struct MleWindow(pub mle::MleEstimator);

impl MleWindow {
    pub fn new(window: usize) -> Self {
        MleWindow(mle::MleEstimator::new(window))
    }
}

impl WindowEstimator for MleWindow {
    fn observe(&mut self, lifetime: f64) {
        RateEstimator::observe(&mut self.0, lifetime);
    }

    fn rate(&self) -> Option<f64> {
        RateEstimator::rate(&self.0)
    }

    fn lifetimes(&self) -> &[f64] {
        self.0.window_slice()
    }

    fn reset(&mut self) {
        RateEstimator::reset(&mut self.0);
    }

    fn n_observed(&self) -> u64 {
        RateEstimator::n_observed(&self.0)
    }

    fn name(&self) -> &'static str {
        "mle"
    }
}

/// Adapter giving any [`RateEstimator`] a planner-compatible window: `n`
/// pseudo-observations of `1/μ̂` (the MLE over that window is exactly μ̂).
/// The pseudo window is re-materialized on observe (refills of a
/// `pseudo_obs`-slot buffer), so `lifetimes()` is a borrow, not a build.
pub struct RateWindow<E: RateEstimator> {
    inner: E,
    /// Pseudo-observation count handed to the planner once warm.
    pseudo_obs: usize,
    /// Cached pseudo window (empty while the inner estimator is cold).
    pseudo: Vec<f64>,
}

impl<E: RateEstimator> RateWindow<E> {
    pub fn new(inner: E) -> Self {
        let mut w = RateWindow { inner, pseudo_obs: 16, pseudo: Vec::new() };
        // Estimators with informative priors (e.g. the §5 hybrid) report
        // a rate before any observation — materialize their window now.
        w.refresh_pseudo();
        w
    }

    pub fn inner(&self) -> &E {
        &self.inner
    }

    fn refresh_pseudo(&mut self) {
        self.pseudo.clear();
        if let Some(r) = self.inner.rate() {
            if r > 0.0 && r.is_finite() {
                self.pseudo.resize(self.pseudo_obs, 1.0 / r);
            }
        }
    }
}

impl<E: RateEstimator> WindowEstimator for RateWindow<E> {
    fn observe(&mut self, lifetime: f64) {
        self.inner.observe(lifetime);
        self.refresh_pseudo();
    }

    fn rate(&self) -> Option<f64> {
        self.inner.rate()
    }

    fn lifetimes(&self) -> &[f64] {
        &self.pseudo
    }

    fn reset(&mut self) {
        self.inner.reset();
        self.refresh_pseudo();
    }

    fn n_observed(&self) -> u64 {
        self.inner.n_observed()
    }

    fn name(&self) -> &'static str {
        self.inner.name()
    }
}

/// Resolve a spec into a live estimator. `window` is the scenario's
/// estimator window K (used by the windowed kinds).
pub fn build_window_estimator(spec: &EstimatorSpec, window: usize) -> Box<dyn WindowEstimator> {
    match spec {
        EstimatorSpec::Mle => Box::new(MleWindow::new(window)),
        EstimatorSpec::Ewma { alpha } => {
            Box::new(RateWindow::new(ewma::EwmaEstimator::new(*alpha)))
        }
        EstimatorSpec::Count => Box::new(RateWindow::new(count::CountEstimator::new())),
        EstimatorSpec::Hybrid { mean, confidence } => Box::new(RateWindow::new(
            hybrid::HybridEstimator::from_history(1.0 / mean.max(1e-9), *confidence, window),
        )),
        EstimatorSpec::Gossip { fanout } => {
            Box::new(RateWindow::new(gossip::GossipEstimator::new(*fanout, window)))
        }
        EstimatorSpec::Categorized => {
            Box::new(RateWindow::new(categorized::CategorizedEstimator::new(window)))
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mle_window_matches_underlying_estimator() {
        let mut w = build_window_estimator(&EstimatorSpec::Mle, 8);
        for _ in 0..8 {
            w.observe(100.0);
        }
        assert!((w.rate().unwrap() - 0.01).abs() < 1e-12);
        assert_eq!(w.lifetimes(), &[100.0; 8][..]);
        assert_eq!(w.name(), "mle");
    }

    #[test]
    fn reset_equals_fresh_for_every_spec() {
        // The scratch-reuse contract: reset() + N observes must be
        // indistinguishable from a new estimator fed the same N observes.
        for spec in [
            EstimatorSpec::Mle,
            EstimatorSpec::Ewma { alpha: 0.2 },
            EstimatorSpec::Count,
            EstimatorSpec::Hybrid { mean: 7200.0, confidence: 16.0 },
            EstimatorSpec::Gossip { fanout: 4 },
            EstimatorSpec::Categorized,
        ] {
            let mut reused = build_window_estimator(&spec, 16);
            for i in 0..40 {
                reused.observe(100.0 + i as f64);
            }
            reused.reset();
            let mut fresh = build_window_estimator(&spec, 16);
            for i in 0..24 {
                reused.observe(400.0 + i as f64);
                fresh.observe(400.0 + i as f64);
            }
            assert_eq!(reused.rate(), fresh.rate(), "{spec:?} rate diverged");
            assert_eq!(
                reused.lifetimes(),
                fresh.lifetimes(),
                "{spec:?} window diverged"
            );
            assert_eq!(reused.n_observed(), fresh.n_observed(), "{spec:?}");
        }
    }

    #[test]
    fn rate_window_pseudo_observations_recover_rate() {
        let mut w = build_window_estimator(&EstimatorSpec::Ewma { alpha: 0.5 }, 64);
        assert!(w.lifetimes().is_empty(), "cold estimator exposes no window");
        for _ in 0..16 {
            w.observe(200.0);
        }
        let lifetimes = w.lifetimes();
        assert!(!lifetimes.is_empty());
        // Planner-side MLE over the pseudo window == the estimator's rate.
        let mu = lifetimes.len() as f64 / lifetimes.iter().sum::<f64>();
        assert!((mu - w.rate().unwrap()).abs() < 1e-12);
    }

    #[test]
    fn all_specs_build() {
        for spec in [
            EstimatorSpec::Mle,
            EstimatorSpec::Ewma { alpha: 0.2 },
            EstimatorSpec::Count,
            EstimatorSpec::Hybrid { mean: 7200.0, confidence: 16.0 },
            EstimatorSpec::Gossip { fanout: 4 },
            EstimatorSpec::Categorized,
        ] {
            let mut e = build_window_estimator(&spec, 32);
            for _ in 0..32 {
                e.observe(500.0);
            }
            let r = e.rate().expect("warm estimator must report a rate");
            assert!(r.is_finite() && r > 0.0, "{spec:?}: {r}");
            // The hybrid is still blending in its (deliberately wrong)
            // 7200 s prior at n=32; the others sit on the data.
            if !matches!(spec, EstimatorSpec::Hybrid { .. }) {
                assert!((r - 1.0 / 500.0).abs() < 1.0 / 500.0 * 0.25, "{spec:?}: {r}");
            } else {
                assert!(r > 1.0 / 7200.0 && r < 1.0 / 500.0, "{spec:?}: {r}");
            }
        }
    }
}
