//! History + online hybrid estimation — the paper's §5 future work:
//! *"study both the possibility and the feasibility of combining the
//! historical log and the real time network conditions observation data to
//! predict with higher accuracy."*
//!
//! Bayesian treatment: exponential lifetimes with a Gamma(α₀, β₀) prior on
//! the rate μ (conjugate). The prior encodes the historical log — e.g.
//! "last week this network averaged 2-hour sessions, worth ~16
//! observations of confidence". The posterior after observing lifetimes
//! t₁…tₙ is Gamma(α₀+n, β₀+Σtᵢ), posterior-mean rate
//! `(α₀+n)/(β₀+Σt)` — smoothly interpolating from pure history (n = 0,
//! exactly the Mickens/Noble-style cold-start fix the paper's related-work
//! section wants) to pure MLE (n ≫ α₀).
//!
//! A sliding window keeps the likelihood term fresh so non-stationary
//! churn (Fig. 4 right) is still tracked.

use super::RateEstimator;
use std::collections::VecDeque;

/// Gamma-prior + windowed-likelihood rate estimator with power-prior
/// discounting: each real observation multiplies the prior's weight by
/// `discount`, so history dominates the cold start and then gracefully
/// yields to live data (guaranteeing convergence even when the historical
/// log is stale — the failure mode the paper's related-work section holds
/// against pure log-based prediction \[13, 17\]).
#[derive(Debug, Clone)]
pub struct HybridEstimator {
    /// Prior pseudo-observation count (history confidence).
    alpha0: f64,
    /// Prior pseudo-total-lifetime (history mean = alpha0/beta0... rate).
    beta0: f64,
    /// Power-prior discount per observation (1.0 = classic conjugate).
    discount: f64,
    window: VecDeque<f64>,
    capacity: usize,
    sum: f64,
    n_total: u64,
}

impl HybridEstimator {
    /// Prior from a historical mean rate and a confidence expressed as an
    /// equivalent number of observations.
    pub fn from_history(historical_rate: f64, confidence_obs: f64, window: usize) -> Self {
        assert!(historical_rate > 0.0 && confidence_obs >= 0.0 && window > 0);
        HybridEstimator {
            alpha0: confidence_obs,
            beta0: confidence_obs / historical_rate,
            discount: 0.96,
            window: VecDeque::with_capacity(window),
            capacity: window,
            sum: 0.0,
            n_total: 0,
        }
    }

    /// Remaining prior weight after the observations seen so far.
    fn prior_weight(&self) -> f64 {
        self.discount.powi(self.n_total.min(i32::MAX as u64) as i32)
    }

    /// Effective sample size (discounted prior + window).
    pub fn effective_n(&self) -> f64 {
        self.alpha0 * self.prior_weight() + self.window.len() as f64
    }
}

impl RateEstimator for HybridEstimator {
    fn observe(&mut self, lifetime: f64) {
        let lifetime = lifetime.max(1e-6);
        if self.window.len() == self.capacity {
            if let Some(old) = self.window.pop_front() {
                self.sum -= old;
            }
        }
        self.window.push_back(lifetime);
        self.sum += lifetime;
        self.n_total += 1;
    }

    fn rate(&self) -> Option<f64> {
        let w = self.prior_weight();
        let alpha = self.alpha0 * w + self.window.len() as f64;
        let beta = self.beta0 * w + self.sum;
        if alpha <= 0.0 || beta <= 0.0 {
            return None;
        }
        Some(alpha / beta)
    }

    fn reset(&mut self) {
        self.window.clear();
        self.sum = 0.0;
        self.n_total = 0;
    }

    fn n_observed(&self) -> u64 {
        self.n_total
    }

    fn name(&self) -> &'static str {
        "hybrid"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::estimator::mle::MleEstimator;
    use crate::util::rng::Pcg64;

    #[test]
    fn cold_start_answers_from_history() {
        let h = HybridEstimator::from_history(1.0 / 7200.0, 16.0, 64);
        // Zero observations: pure prior.
        let r = h.rate().unwrap();
        assert!((r - 1.0 / 7200.0).abs() < 1e-12);
        assert_eq!(h.effective_n(), 16.0);
    }

    #[test]
    fn converges_to_data_with_enough_observations() {
        // History says 7200 s but the network now runs at 1800 s: the
        // posterior must move to the data.
        let mut rng = Pcg64::new(61, 0);
        let mut h = HybridEstimator::from_history(1.0 / 7200.0, 16.0, 128);
        for _ in 0..128 {
            h.observe(rng.exp(1.0 / 1800.0));
        }
        let r = h.rate().unwrap();
        let truth = 1.0 / 1800.0;
        assert!(
            (r - truth).abs() < truth * 0.25,
            "posterior {r} should be near the new rate {truth}"
        );
    }

    #[test]
    fn cold_start_beats_pure_mle_when_history_is_right() {
        // First few observations: the MLE is high-variance, the hybrid is
        // anchored. Compare mean absolute error over many cold starts.
        let mut rng = Pcg64::new(62, 0);
        let truth = 1.0 / 7200.0;
        let (mut err_h, mut err_m) = (0.0, 0.0);
        let trials = 400;
        for _ in 0..trials {
            let mut h = HybridEstimator::from_history(truth * 1.1, 16.0, 64); // 10% stale history
            let mut m = MleEstimator::new(64).with_min_obs(1);
            for _ in 0..4 {
                let x = rng.exp(truth);
                h.observe(x);
                m.observe(x);
            }
            err_h += (h.rate().unwrap() - truth).abs();
            err_m += (m.rate().unwrap() - truth).abs();
        }
        assert!(
            err_h < err_m * 0.55,
            "hybrid cold-start err {err_h} vs mle {err_m}"
        );
    }

    #[test]
    fn stale_history_is_outgrown() {
        // Badly wrong history (10x) must be dominated by a full window.
        let mut rng = Pcg64::new(63, 0);
        let truth = 1.0 / 3600.0;
        let mut h = HybridEstimator::from_history(truth / 10.0, 16.0, 256);
        for _ in 0..256 {
            h.observe(rng.exp(truth));
        }
        let r = h.rate().unwrap();
        assert!((r - truth).abs() < truth * 0.25, "posterior {r} vs {truth}");
    }

    #[test]
    fn reset_reuse_is_byte_identical_to_fresh() {
        // reset() keeps the prior (configuration) and drops the evidence:
        // a reused estimator must be bit-for-bit a fresh one afterwards.
        let mut rng = Pcg64::new(65, 0);
        let mut reused = HybridEstimator::from_history(1.0 / 7200.0, 16.0, 64);
        for _ in 0..200 {
            reused.observe(rng.exp(1.0 / 1200.0));
        }
        reused.reset();
        let mut fresh = HybridEstimator::from_history(1.0 / 7200.0, 16.0, 64);
        assert_eq!(reused.rate().map(f64::to_bits), fresh.rate().map(f64::to_bits));
        let mut replay = Pcg64::new(66, 0);
        for _ in 0..120 {
            let x = replay.exp(1.0 / 3000.0);
            reused.observe(x);
            fresh.observe(x);
        }
        assert_eq!(
            reused.rate().map(f64::to_bits),
            fresh.rate().map(f64::to_bits),
            "posterior must be bit-identical after reuse"
        );
        assert_eq!(reused.effective_n().to_bits(), fresh.effective_n().to_bits());
        assert_eq!(reused.n_observed(), fresh.n_observed());
    }

    #[test]
    fn window_keeps_it_adaptive() {
        // Rate doubles: the windowed likelihood tracks it like the MLE.
        let mut rng = Pcg64::new(64, 0);
        let mut h = HybridEstimator::from_history(1e-3, 8.0, 32);
        for _ in 0..64 {
            h.observe(rng.exp(1e-3));
        }
        for _ in 0..32 {
            h.observe(rng.exp(2e-3));
        }
        let r = h.rate().unwrap();
        assert!(
            (r - 2e-3).abs() < 2e-3 * 0.35,
            "windowed posterior {r} should track the doubled rate"
        );
    }
}
