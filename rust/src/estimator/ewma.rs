//! EWMA rate estimator — comparison baseline from the companion study \[15\].
//!
//! Smooths the inverse of each observed lifetime. Reacts faster than the
//! windowed MLE on rate jumps but is noisier (1/t of a single short session
//! is a high-variance sample); the ablation bench quantifies the trade.

use super::RateEstimator;

/// Exponentially-weighted moving average over per-observation rates.
#[derive(Debug, Clone)]
pub struct EwmaEstimator {
    alpha: f64,
    /// EWMA of observed lifetimes (smoothing the *lifetime* and inverting
    /// is far less noisy than smoothing the inverse).
    mean_lifetime: Option<f64>,
    n: u64,
    min_obs: u64,
}

impl EwmaEstimator {
    pub fn new(alpha: f64) -> Self {
        assert!((0.0..=1.0).contains(&alpha) && alpha > 0.0);
        EwmaEstimator { alpha, mean_lifetime: None, n: 0, min_obs: 8 }
    }

    pub fn with_min_obs(mut self, min_obs: u64) -> Self {
        self.min_obs = min_obs.max(1);
        self
    }
}

impl RateEstimator for EwmaEstimator {
    fn observe(&mut self, lifetime: f64) {
        let lifetime = lifetime.max(1e-6);
        self.mean_lifetime = Some(match self.mean_lifetime {
            None => lifetime,
            Some(m) => m + self.alpha * (lifetime - m),
        });
        self.n += 1;
    }

    fn rate(&self) -> Option<f64> {
        if self.n < self.min_obs {
            return None;
        }
        self.mean_lifetime.map(|m| 1.0 / m)
    }

    fn reset(&mut self) {
        self.mean_lifetime = None;
        self.n = 0;
    }

    fn n_observed(&self) -> u64 {
        self.n
    }

    fn name(&self) -> &'static str {
        "ewma"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn constant_input_exact() {
        let mut e = EwmaEstimator::new(0.1);
        for _ in 0..50 {
            e.observe(200.0);
        }
        assert!((e.rate().unwrap() - 0.005).abs() < 1e-12);
    }

    #[test]
    fn tracks_rate_doubling_faster_than_wide_mle() {
        use crate::estimator::mle::MleEstimator;
        let mut rng = Pcg64::new(20, 0);
        let mut ewma = EwmaEstimator::new(0.2);
        let mut mle = MleEstimator::new(256);
        // Long phase at rate r, then switch to 2r for only 32 observations.
        let r = 1e-3;
        for _ in 0..256 {
            let x = rng.exp(r);
            ewma.observe(x);
            mle.observe(x);
        }
        for _ in 0..32 {
            let x = rng.exp(2.0 * r);
            ewma.observe(x);
            mle.observe(x);
        }
        let e_err = (ewma.rate().unwrap() - 2.0 * r).abs();
        let m_err = (mle.rate().unwrap() - 2.0 * r).abs();
        assert!(e_err < m_err, "ewma {e_err} should beat wide-window mle {m_err}");
    }

    #[test]
    fn unbiased_enough_on_stationary_data() {
        let mut rng = Pcg64::new(21, 0);
        let mut e = EwmaEstimator::new(0.05);
        let r = 1.0 / 7200.0;
        for _ in 0..2000 {
            e.observe(rng.exp(r));
        }
        let got = e.rate().unwrap();
        assert!((got - r).abs() < r * 0.3, "got {got} want {r}");
    }
}
