//! Section 3.1.2 / 3.1.3: online estimation of the checkpoint overhead V
//! (Eq. 2) and the image download time T_d.
//!
//! Eq. 2 calibration: run the job for `t` minutes with checkpointing off,
//! recording mean CPU share `P₁` and message count `M₁`; then `t` minutes
//! with a small interval (y checkpoints), recording `P₂`, `M₂`:
//!
//! ```text
//! V = (P₁ − P₂)(M₁ − M₂) t / (2 P₁ M₁ y)
//! ```
//!
//! T_d starts at V (Section 3.1.3), is replaced by the measured background
//! download of the first image, and thereafter by the most recent actual
//! restart download.

/// State machine for the Eq. 2 two-phase calibration.
#[derive(Debug, Clone, PartialEq)]
pub enum Calibration {
    /// Phase 1 (checkpointing off) in progress since `started`.
    BaselineRunning { started: f64 },
    /// Phase 1 done; phase 2 (checkpointing on) since `started`.
    ProbeRunning { started: f64, p1: f64, m1: f64 },
    /// Both phases done.
    Done { v: f64 },
}

/// Collects the phase statistics and produces V.
#[derive(Debug, Clone)]
pub struct VEstimator {
    pub phase_len: f64,
    pub state: Calibration,
}

impl VEstimator {
    /// `phase_len`: the t in Eq. 2 (seconds per phase).
    pub fn new(phase_len: f64, now: f64) -> Self {
        assert!(phase_len > 0.0);
        VEstimator { phase_len, state: Calibration::BaselineRunning { started: now } }
    }

    /// Finish phase 1 with its measurements.
    pub fn finish_baseline(&mut self, now: f64, p1: f64, m1: f64) {
        debug_assert!(matches!(self.state, Calibration::BaselineRunning { .. }));
        self.state = Calibration::ProbeRunning { started: now, p1, m1 };
    }

    /// Finish phase 2; `y` = checkpoints taken during the probe phase.
    /// Uses the two-channel mean form (see [`eq2_v_mean`] for why).
    pub fn finish_probe(&mut self, p2: f64, m2: f64, y: u64) -> f64 {
        let Calibration::ProbeRunning { p1, m1, .. } = self.state else {
            panic!("finish_probe before finish_baseline");
        };
        let v = eq2_v_mean(p1, p2, m1, m2, self.phase_len, y);
        self.state = Calibration::Done { v };
        v
    }

    pub fn value(&self) -> Option<f64> {
        match self.state {
            Calibration::Done { v } => Some(v),
            _ => None,
        }
    }
}

/// Eq. 2 exactly as printed in the paper (product form):
/// `V = (P₁−P₂)(M₁−M₂) t / (2 P₁ M₁ y)`.
///
/// NOTE (reproduction finding, see DESIGN.md §Substitutions): under the
/// natural linear slowdown model (checkpointing for a fraction
/// `f = V/(T+V)` of the probe phase scales both P and M by `1−f`) this
/// evaluates to `V²/(2(T+V))`, NOT `V`. The surrounding text — "we
/// estimate two separate V based on both the CPU usage and network IO
/// statistics" — indicates the intended estimator is the *mean* of the
/// two single-channel estimates ([`eq2_v_mean`]), which does recover `V`.
/// We keep the literal form for fidelity and use the mean form in the
/// calibration pipeline.
pub fn eq2_v(p1: f64, p2: f64, m1: f64, m2: f64, t: f64, y: u64) -> f64 {
    ((p1 - p2) * (m1 - m2) * t / (2.0 * p1 * m1 * y.max(1) as f64)).max(0.0)
}

/// The two-channel *mean* estimator the paper's prose describes:
/// `V = [ (P₁−P₂)/P₁ + (M₁−M₂)/M₁ ] · t / (2 y)` — the average of the
/// CPU-based and message-based single-channel estimates. Recovers the true
/// V exactly under the linear slowdown model (verified in
/// `rust/tests/estimation_pipeline.rs`).
pub fn eq2_v_mean(p1: f64, p2: f64, m1: f64, m2: f64, t: f64, y: u64) -> f64 {
    let y = y.max(1) as f64;
    let dp = ((p1 - p2) / p1.max(1e-12)).max(0.0);
    let dm = ((m1 - m2) / m1.max(1e-12)).max(0.0);
    ((dp + dm) * t / (2.0 * y)).max(0.0)
}

/// T_d tracking per Section 3.1.3.
#[derive(Debug, Clone)]
pub struct TdEstimator {
    current: f64,
    source: TdSource,
}

/// Provenance of the current T_d estimate.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TdSource {
    /// Initialized from V (no download observed yet).
    SeededFromV,
    /// Background probe download of the first checkpoint image.
    BackgroundProbe,
    /// An actual restart's measured download.
    Restart,
}

impl TdEstimator {
    /// Seed with the V estimate (Section 3.1.3: "we set T_d to be same as
    /// V as its initial value").
    pub fn seeded_from_v(v: f64) -> Self {
        TdEstimator { current: v.max(0.0), source: TdSource::SeededFromV }
    }

    /// First image captured: a background download measures T_d properly.
    pub fn record_probe(&mut self, measured: f64) {
        if self.source != TdSource::Restart {
            self.current = measured.max(0.0);
            self.source = TdSource::BackgroundProbe;
        }
    }

    /// A restart happened: its download time is the freshest truth and
    /// always wins (recency priority, Section 3.1.3).
    pub fn record_restart(&mut self, measured: f64) {
        self.current = measured.max(0.0);
        self.source = TdSource::Restart;
    }

    pub fn value(&self) -> f64 {
        self.current
    }

    pub fn source(&self) -> TdSource {
        self.source
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn eq2_basic() {
        // Checkpointing halves CPU share and message throughput over a
        // t=600 s probe with y=10 checkpoints:
        // V = (0.5 * M1/2 * 600) / (2 * 1.0 * M1 * 10) = 7.5 s
        let v = eq2_v(1.0, 0.5, 1000.0, 500.0, 600.0, 10);
        assert!((v - 7.5).abs() < 1e-12);
    }

    #[test]
    fn eq2_no_slowdown_gives_zero() {
        assert_eq!(eq2_v(0.9, 0.9, 800.0, 800.0, 600.0, 10), 0.0);
        // Noise making P2 > P1 must not go negative.
        assert_eq!(eq2_v(0.9, 0.95, 800.0, 790.0, 600.0, 10), 0.0);
    }

    #[test]
    fn calibration_state_machine() {
        let mut c = VEstimator::new(600.0, 0.0);
        assert!(c.value().is_none());
        c.finish_baseline(600.0, 1.0, 1000.0);
        assert!(c.value().is_none());
        // Both channels halved with y=10 checkpoints in 600 s: the cycle
        // is 60 s and half of it is checkpointing, so V = 30 s — which the
        // mean form recovers exactly.
        let v = c.finish_probe(0.5, 500.0, 10);
        assert!((v - 30.0).abs() < 1e-12);
        assert_eq!(c.value(), Some(v));
    }

    #[test]
    fn mean_form_recovers_v_product_form_does_not() {
        // Linear slowdown model: probe interval T=160, V=20 => f = 1/9.
        let (t, iv, true_v) = (1800.0f64, 160.0f64, 20.0f64);
        let f = true_v / (iv + true_v);
        let y = (t / (iv + true_v)).floor() as u64;
        let (p1, m1) = (1.0, 1000.0);
        let (p2, m2) = (p1 * (1.0 - f), m1 * (1.0 - f));
        let mean = eq2_v_mean(p1, p2, m1, m2, t, y);
        assert!((mean - true_v).abs() < true_v * 0.01, "mean {mean}");
        let product = eq2_v(p1, p2, m1, m2, t, y);
        // The literal printed form lands at ~V^2/(2(T+V)) ≈ 1.1 s.
        assert!(product < true_v * 0.2, "product {product}");
    }

    #[test]
    #[should_panic(expected = "finish_probe before finish_baseline")]
    fn calibration_order_enforced() {
        let mut c = VEstimator::new(600.0, 0.0);
        c.finish_probe(0.5, 500.0, 10);
    }

    #[test]
    fn td_lifecycle() {
        let mut td = TdEstimator::seeded_from_v(20.0);
        assert_eq!(td.value(), 20.0);
        assert_eq!(td.source(), TdSource::SeededFromV);
        td.record_probe(47.0);
        assert_eq!(td.value(), 47.0);
        assert_eq!(td.source(), TdSource::BackgroundProbe);
        td.record_restart(61.0);
        assert_eq!(td.value(), 61.0);
        // A later probe must NOT override restart truth.
        td.record_probe(10.0);
        assert_eq!(td.value(), 61.0);
        assert_eq!(td.source(), TdSource::Restart);
        // But a newer restart does.
        td.record_restart(55.0);
        assert_eq!(td.value(), 55.0);
    }
}
