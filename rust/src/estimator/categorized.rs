//! Category-stratified estimation — after Tian & Dai \[22\], cited in
//! Section 3: *"once peers are grouped into different categories according
//! to their average life time (e.g. long, medium and short life time),
//! peers' failure can be even better fitted to the exponential
//! distribution."*
//!
//! The estimator maintains per-category windowed MLEs with data-driven
//! boundaries (rolling tertiles) and reports the rate of the mixture a
//! *job's member* actually experiences. For genuinely-mixed populations
//! (e.g. a Weibull heavy tail ≈ mixture of exponentials) the stratified
//! fit tracks the hazard far better than a single pooled MLE.

use super::mle::MleEstimator;
use super::RateEstimator;
use std::collections::VecDeque;

/// Number of lifetime categories (short / medium / long, per \[22\]).
pub const CATEGORIES: usize = 3;

/// Stratified windowed-MLE estimator.
#[derive(Debug, Clone)]
pub struct CategorizedEstimator {
    /// Recent raw lifetimes used to maintain the category boundaries.
    boundary_window: VecDeque<f64>,
    boundary_capacity: usize,
    /// Per-category estimators (index 0 = shortest lifetimes).
    per_category: Vec<MleEstimator>,
    /// Observation counts per category (mixture weights).
    counts: Vec<u64>,
    n_total: u64,
}

impl CategorizedEstimator {
    pub fn new(window_per_category: usize) -> Self {
        CategorizedEstimator {
            boundary_window: VecDeque::with_capacity(256),
            boundary_capacity: 256,
            per_category: (0..CATEGORIES)
                .map(|_| MleEstimator::new(window_per_category).with_min_obs(4))
                .collect(),
            counts: vec![0; CATEGORIES],
            n_total: 0,
        }
    }

    /// Current category boundaries (tertiles of the boundary window).
    pub fn boundaries(&self) -> Option<(f64, f64)> {
        if self.boundary_window.len() < 9 {
            return None;
        }
        let mut v: Vec<f64> = self.boundary_window.iter().copied().collect();
        v.sort_by(|a, b| a.partial_cmp(b).unwrap());
        let lo = v[v.len() / 3];
        let hi = v[2 * v.len() / 3];
        Some((lo, hi))
    }

    fn categorize(&self, lifetime: f64) -> usize {
        match self.boundaries() {
            None => 1, // no boundaries yet: treat as "medium"
            Some((lo, hi)) => {
                if lifetime < lo {
                    0
                } else if lifetime < hi {
                    1
                } else {
                    2
                }
            }
        }
    }

    /// Per-category rates (None where too few observations).
    pub fn category_rates(&self) -> Vec<Option<f64>> {
        self.per_category.iter().map(|e| e.rate()).collect()
    }

    /// Mixture weights observed so far.
    pub fn weights(&self) -> Vec<f64> {
        let total: u64 = self.counts.iter().sum();
        if total == 0 {
            return vec![0.0; CATEGORIES];
        }
        self.counts.iter().map(|&c| c as f64 / total as f64).collect()
    }
}

impl RateEstimator for CategorizedEstimator {
    fn observe(&mut self, lifetime: f64) {
        let lifetime = lifetime.max(1e-6);
        if self.boundary_window.len() == self.boundary_capacity {
            self.boundary_window.pop_front();
        }
        self.boundary_window.push_back(lifetime);
        let cat = self.categorize(lifetime);
        self.per_category[cat].observe(lifetime);
        self.counts[cat] += 1;
        self.n_total += 1;
    }

    /// The population failure rate: observed failures per observed
    /// lifetime across categories — `Σ nᵢ / Σ (nᵢ/μ̂ᵢ)` (the pooled MLE is
    /// recovered exactly when all categories agree, but the stratification
    /// keeps each fit locally exponential per \[22\]).
    fn rate(&self) -> Option<f64> {
        let mut n = 0.0;
        let mut t = 0.0;
        for (i, est) in self.per_category.iter().enumerate() {
            if let Some(mu) = est.rate() {
                let ni = self.counts[i].min(est.window_len() as u64) as f64;
                n += ni;
                t += ni / mu;
            }
        }
        if t > 0.0 && n > 0.0 {
            Some(n / t)
        } else {
            None
        }
    }

    fn reset(&mut self) {
        self.boundary_window.clear();
        for est in &mut self.per_category {
            est.reset();
        }
        for c in &mut self.counts {
            *c = 0;
        }
        self.n_total = 0;
    }

    fn n_observed(&self) -> u64 {
        self.n_total
    }

    fn name(&self) -> &'static str {
        "categorized"
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Pcg64;

    #[test]
    fn single_population_matches_pooled_mle() {
        let mut rng = Pcg64::new(71, 0);
        let truth = 1.0 / 7200.0;
        let mut c = CategorizedEstimator::new(64);
        for _ in 0..600 {
            c.observe(rng.exp(truth));
        }
        let r = c.rate().unwrap();
        assert!((r - truth).abs() < truth * 0.2, "rate {r} vs {truth}");
    }

    #[test]
    fn boundaries_are_tertiles() {
        let mut c = CategorizedEstimator::new(64);
        for i in 1..=99 {
            c.observe(i as f64);
        }
        let (lo, hi) = c.boundaries().unwrap();
        assert!((lo - 33.0).abs() < 3.0, "lo {lo}");
        assert!((hi - 66.0).abs() < 3.0, "hi {hi}");
    }

    #[test]
    fn mixture_population_stratifies() {
        // 50/50 mixture of 10-min and 10-hour peers (the Tian-Dai case):
        // per-category rates must separate by >1 order of magnitude.
        let mut rng = Pcg64::new(72, 0);
        let mut c = CategorizedEstimator::new(64);
        for _ in 0..2000 {
            let rate = if rng.next_f64() < 0.5 { 1.0 / 600.0 } else { 1.0 / 36_000.0 };
            c.observe(rng.exp(rate));
        }
        let rates = c.category_rates();
        let short = rates[0].unwrap();
        let long = rates[2].unwrap();
        assert!(
            short > 10.0 * long,
            "short-category rate {short} should dwarf long-category {long}"
        );
        // Weights roughly balanced across categories by construction.
        let w = c.weights();
        assert!(w.iter().all(|&x| x > 0.15), "weights {w:?}");
    }

    #[test]
    fn mixture_rate_matches_population_failure_rate() {
        // Population failure rate = failures per peer-second =
        // n / sum(lifetimes). Compare against the stratified estimate.
        let mut rng = Pcg64::new(73, 0);
        let mut c = CategorizedEstimator::new(256);
        let mut n = 0.0;
        let mut total = 0.0;
        for _ in 0..3000 {
            let rate = if rng.next_f64() < 0.7 { 1.0 / 1200.0 } else { 1.0 / 20_000.0 };
            let x = rng.exp(rate);
            c.observe(x);
            n += 1.0;
            total += x;
        }
        let truth = n / total;
        let r = c.rate().unwrap();
        assert!(
            (r - truth).abs() < truth * 0.35,
            "stratified {r} vs population {truth}"
        );
    }

    #[test]
    fn reset_reuse_is_byte_identical_to_fresh() {
        // Same discipline as the WindowEstimator scratch contract:
        // reset() + N observes must be bit-for-bit a fresh estimator fed
        // the same N observes — boundaries, weights, and rate included.
        let mut rng = Pcg64::new(74, 0);
        let mut reused = CategorizedEstimator::new(64);
        for _ in 0..500 {
            reused.observe(rng.exp(1.0 / 900.0));
        }
        reused.reset();
        let mut fresh = CategorizedEstimator::new(64);
        let mut replay = Pcg64::new(75, 0);
        for _ in 0..300 {
            let x = replay.exp(1.0 / 4000.0);
            reused.observe(x);
            fresh.observe(x);
        }
        assert_eq!(
            reused.rate().map(f64::to_bits),
            fresh.rate().map(f64::to_bits),
            "pooled rate must be bit-identical"
        );
        assert_eq!(reused.boundaries(), fresh.boundaries());
        assert_eq!(reused.counts, fresh.counts);
        assert_eq!(reused.n_observed(), fresh.n_observed());
        let (rw, fw) = (reused.weights(), fresh.weights());
        assert_eq!(
            rw.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            fw.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn needs_data_before_answering() {
        let mut c = CategorizedEstimator::new(64);
        assert!(c.rate().is_none());
        for _ in 0..3 {
            c.observe(100.0);
        }
        assert!(c.rate().is_none(), "min_obs per category not met yet");
    }
}
