//! simlint — the determinism lint pass for the p2pcp simulation core.
//!
//! Every figure this repro emits rests on the simulation being strictly
//! deterministic (seed in → bytes out, for any thread count). This pass
//! enforces the static half of that contract over sim-visible modules
//! (everything under `rust/src`); the runtime half is the dual-run digest
//! harness in `rust/tests/determinism.rs` (see DESIGN.md §Determinism
//! contract).
//!
//! ## Rules
//!
//! * `unordered` — no `HashMap` / `HashSet`: their iteration order is
//!   nondeterministic and silently leaks into simulation state the moment
//!   anyone iterates. Use `BTreeMap` / `p2pcp::util::detmap::DetMap` /
//!   `Vec` slabs, or annotate a genuinely never-iterated map with
//!   `// simlint: allow(unordered, reason = "…")` — the pass then verifies
//!   the annotated container is never iterated or folded.
//! * `wall_clock` — no wall-clock or OS-environment reads (`Instant`,
//!   `SystemTime`, `thread_rng`, `from_entropy`, `std::env::…`) outside
//!   the allowlisted host boundary (`src/main.rs`, `src/cli.rs`,
//!   `src/util/wall_clock.rs`).
//! * `float_reduce` — no `.sum()` / `.product()` / `.fold()` over an
//!   unordered-container iterator: float addition is not associative, so
//!   the result depends on iteration order.
//! * `truncating_cast` — no bare `f64 as u64`-style truncating casts in
//!   accounting code: make the rounding explicit (`.floor()`, `.ceil()`,
//!   `.round()`, `.trunc()`) or annotate the deliberate truncation.
//!
//! ## Implementation
//!
//! The offline crate cache has no `syn`, so the pass runs on its own
//! comment/string-aware token scanner: comments and string literals are
//! blanked (annotations are read from the line comments first), the rest
//! is tokenized, and the rules are syntactic patterns over the token
//! stream. That makes the pass an approximation by construction — the
//! dual-run digest harness is the backstop for whatever it misses.

use std::collections::{BTreeMap, BTreeSet};
use std::fmt;
use std::fs;
use std::io;
use std::path::{Path, PathBuf};

/// The rule classes the pass enforces.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Rule {
    Unordered,
    WallClock,
    FloatReduce,
    TruncatingCast,
    /// A simlint annotation comment that does not parse — always an
    /// error, so a typo can never silently disable a real rule.
    BadAnnotation,
}

impl Rule {
    pub fn key(self) -> &'static str {
        match self {
            Rule::Unordered => "unordered",
            Rule::WallClock => "wall_clock",
            Rule::FloatReduce => "float_reduce",
            Rule::TruncatingCast => "truncating_cast",
            Rule::BadAnnotation => "bad_annotation",
        }
    }
}

/// One finding, with the span it anchors to.
#[derive(Debug, Clone)]
pub struct Violation {
    pub file: String,
    pub line: u32,
    pub col: u32,
    pub rule: Rule,
    pub msg: String,
}

impl fmt::Display for Violation {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(
            f,
            "{}:{}:{}: {}: {}",
            self.file,
            self.line,
            self.col,
            self.rule.key(),
            self.msg
        )
    }
}

/// Modules allowed to touch the wall clock / process environment: the CLI
/// boundary plus the audited `util::wall_clock` helper everything else
/// must route through.
pub const WALL_CLOCK_EXEMPT: &[&str] =
    &["src/main.rs", "src/cli.rs", "src/util/wall_clock.rs"];

/// True if `path` is inside the wall-clock allowlist (suffix match on
/// `/`-normalized paths).
pub fn wall_clock_exempt(path: &str) -> bool {
    let p = path.replace('\\', "/");
    WALL_CLOCK_EXEMPT.iter().any(|s| p.ends_with(s))
}

/// True if `path` is a bench target (`rust/benches/…`). Benches are
/// measurement drivers, not sim modules, so only the `unordered` and
/// `wall_clock` rules apply there: a bench may legitimately time and
/// aggregate, but it must not smuggle in OS entropy, ad-hoc environment
/// reads (route them through `util::wall_clock`), or unordered
/// containers whose iteration order could leak into emitted tables.
pub fn bench_path(path: &str) -> bool {
    let p = path.replace('\\', "/");
    p.starts_with("benches/") || p.contains("/benches/")
}

// --------------------------------------------------------------- scanner

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum Kind {
    Ident,
    Num,
    Punct,
}

#[derive(Debug, Clone)]
struct Tok {
    text: String,
    kind: Kind,
    line: u32,
    col: u32,
}

struct Stripped {
    /// Source with comments and string/char literals blanked to spaces
    /// (newlines preserved, so token line/col stay true).
    code: String,
    /// Line comments, keyed by starting line (annotation carriers).
    comments: Vec<(u32, String)>,
}

/// Does a raw-string literal start at `chars[i]`? Returns
/// `(hash_count, prefix_len)` covering `(b?)r#*"`.
fn raw_string_start(chars: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if chars.get(j) == Some(&'b') {
        j += 1;
    }
    if chars.get(j) != Some(&'r') {
        return None;
    }
    j += 1;
    let mut hashes = 0;
    while chars.get(j) == Some(&'#') {
        hashes += 1;
        j += 1;
    }
    if chars.get(j) == Some(&'"') {
        Some((hashes, j - i + 1))
    } else {
        None
    }
}

fn strip(src: &str) -> Stripped {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut code = String::with_capacity(src.len());
    let mut comments: Vec<(u32, String)> = Vec::new();
    let mut line: u32 = 1;
    let mut i = 0;
    while i < n {
        let c = chars[i];
        let next = chars.get(i + 1).copied();
        let prev_ident = i > 0 && (chars[i - 1].is_ascii_alphanumeric() || chars[i - 1] == '_');
        if c == '\n' {
            code.push('\n');
            line += 1;
            i += 1;
        } else if c == '/' && next == Some('/') {
            let start = line;
            let mut text = String::new();
            while i < n && chars[i] != '\n' {
                text.push(chars[i]);
                code.push(' ');
                i += 1;
            }
            comments.push((start, text));
        } else if c == '/' && next == Some('*') {
            // Block comments nest in Rust.
            let mut depth = 1u32;
            code.push(' ');
            code.push(' ');
            i += 2;
            while i < n && depth > 0 {
                if chars[i] == '/' && chars.get(i + 1) == Some(&'*') {
                    depth += 1;
                    code.push(' ');
                    code.push(' ');
                    i += 2;
                } else if chars[i] == '*' && chars.get(i + 1) == Some(&'/') {
                    depth -= 1;
                    code.push(' ');
                    code.push(' ');
                    i += 2;
                } else {
                    if chars[i] == '\n' {
                        code.push('\n');
                        line += 1;
                    } else {
                        code.push(' ');
                    }
                    i += 1;
                }
            }
        } else if !prev_ident && raw_string_start(&chars, i).is_some() {
            let (hashes, prefix) = raw_string_start(&chars, i).expect("checked above");
            for _ in 0..prefix {
                code.push(' ');
            }
            i += prefix;
            while i < n {
                let closes = chars[i] == '"'
                    && i + hashes < n
                    && chars[i + 1..=i + hashes].iter().all(|&h| h == '#');
                if closes {
                    for _ in 0..=hashes {
                        code.push(' ');
                    }
                    i += 1 + hashes;
                    break;
                }
                if chars[i] == '\n' {
                    code.push('\n');
                    line += 1;
                } else {
                    code.push(' ');
                }
                i += 1;
            }
        } else if c == 'b' && next == Some('"') && !prev_ident {
            // Plain byte string: blank the prefix, let the `"` branch run.
            code.push(' ');
            i += 1;
        } else if c == '"' {
            code.push(' ');
            i += 1;
            while i < n {
                if chars[i] == '\\' {
                    code.push(' ');
                    code.push(' ');
                    i += 2;
                    continue;
                }
                if chars[i] == '"' {
                    code.push(' ');
                    i += 1;
                    break;
                }
                if chars[i] == '\n' {
                    code.push('\n');
                    line += 1;
                } else {
                    code.push(' ');
                }
                i += 1;
            }
        } else if c == '\'' {
            if next == Some('\\') {
                // Escaped char literal: '\n', '\\', '\u{41}', '\'' …
                code.push(' ');
                code.push(' ');
                code.push(' ');
                i += 3; // quote, backslash, escaped char
                while i < n && chars[i] != '\'' {
                    code.push(' ');
                    i += 1;
                }
                if i < n {
                    code.push(' ');
                    i += 1;
                }
            } else if chars.get(i + 2) == Some(&'\'') && next.is_some() {
                // Plain char literal 'x'.
                code.push(' ');
                code.push(' ');
                code.push(' ');
                i += 3;
            } else {
                // Lifetime: keep the apostrophe as punctuation.
                code.push('\'');
                i += 1;
            }
        } else {
            code.push(c);
            i += 1;
        }
    }
    Stripped { code, comments }
}

fn tokenize(code: &str) -> Vec<Tok> {
    let chars: Vec<char> = code.chars().collect();
    let n = chars.len();
    let mut toks = Vec::new();
    let mut line: u32 = 1;
    let mut col: u32 = 1;
    let mut i = 0;
    while i < n {
        let c = chars[i];
        if c == '\n' {
            line += 1;
            col = 1;
            i += 1;
            continue;
        }
        if c.is_whitespace() {
            col += 1;
            i += 1;
            continue;
        }
        let (tline, tcol) = (line, col);
        if c.is_ascii_alphabetic() || c == '_' {
            let mut s = String::new();
            while i < n && (chars[i].is_ascii_alphanumeric() || chars[i] == '_') {
                s.push(chars[i]);
                i += 1;
                col += 1;
            }
            toks.push(Tok { text: s, kind: Kind::Ident, line: tline, col: tcol });
        } else if c.is_ascii_digit() {
            let mut s = String::new();
            while i < n {
                let ch = chars[i];
                if ch.is_ascii_alphanumeric() || ch == '_' {
                    s.push(ch);
                    i += 1;
                    col += 1;
                    continue;
                }
                let next_digit = chars.get(i + 1).is_some_and(|d| d.is_ascii_digit());
                if ch == '.' && next_digit && !s.contains('.') {
                    s.push('.');
                    i += 1;
                    col += 1;
                    continue;
                }
                let exp = matches!(s.chars().last(), Some('e') | Some('E'))
                    && !s.starts_with("0x")
                    && !s.starts_with("0X");
                if (ch == '+' || ch == '-') && exp && next_digit {
                    s.push(ch);
                    i += 1;
                    col += 1;
                    continue;
                }
                break;
            }
            toks.push(Tok { text: s, kind: Kind::Num, line: tline, col: tcol });
        } else if c == ':' && chars.get(i + 1) == Some(&':') {
            toks.push(Tok { text: "::".to_string(), kind: Kind::Punct, line: tline, col: tcol });
            i += 2;
            col += 2;
        } else {
            toks.push(Tok { text: c.to_string(), kind: Kind::Punct, line: tline, col: tcol });
            i += 1;
            col += 1;
        }
    }
    toks
}

// ----------------------------------------------------------- annotations

#[derive(Debug, Clone)]
struct Allow {
    rule: Rule,
    line: u32,
}

/// Parse `allow(<rule>, reason = "…")` after the annotation marker.
fn parse_allow(rest: &str) -> Result<Rule, String> {
    let Some(body) = rest.strip_prefix("allow(") else {
        return Err("expected `allow(<rule>, reason = \"…\")` after `simlint:`".to_string());
    };
    let Some(stop) = body.find([',', ')']) else {
        return Err("unterminated `allow(…` annotation".to_string());
    };
    let rule_name = body[..stop].trim();
    let rule = match rule_name {
        "unordered" => Rule::Unordered,
        "wall_clock" => Rule::WallClock,
        "float_reduce" => Rule::FloatReduce,
        "truncating_cast" => Rule::TruncatingCast,
        other => {
            return Err(format!(
                "unknown rule `{other}` (expected unordered | wall_clock | \
                 float_reduce | truncating_cast)"
            ))
        }
    };
    let Some(after) = body[stop..].strip_prefix(',') else {
        return Err(format!("allow({rule_name}) is missing `, reason = \"…\"`"));
    };
    let after = after.trim_start();
    let Some(after) = after.strip_prefix("reason") else {
        return Err("expected `reason = \"…\"` after the rule name".to_string());
    };
    let after = after.trim_start();
    let Some(after) = after.strip_prefix('=') else {
        return Err("expected `=` after `reason`".to_string());
    };
    let after = after.trim_start();
    let Some(after) = after.strip_prefix('"') else {
        return Err("the reason must be a quoted string".to_string());
    };
    let Some(endq) = after.find('"') else {
        return Err("unterminated reason string".to_string());
    };
    if after[..endq].trim().is_empty() {
        return Err("the reason must be non-empty — say *why* the rule is safe here".to_string());
    }
    if !after[endq + 1..].trim_start().starts_with(')') {
        return Err("expected `)` after the reason".to_string());
    }
    Ok(rule)
}

fn parse_annotations(
    file: &str,
    comments: &[(u32, String)],
    violations: &mut Vec<Violation>,
) -> Vec<Allow> {
    let mut out = Vec::new();
    for (line, text) in comments {
        let Some(pos) = text.find("simlint:") else { continue };
        let rest = text[pos + "simlint:".len()..].trim_start();
        match parse_allow(rest) {
            Ok(rule) => out.push(Allow { rule, line: *line }),
            Err(msg) => violations.push(Violation {
                file: file.to_string(),
                line: *line,
                col: 1,
                rule: Rule::BadAnnotation,
                msg,
            }),
        }
    }
    out
}

/// Map each allow annotation to the code line it governs: its own line if
/// that line has code, else the next line that does.
fn coverage(allows: &[Allow], token_lines: &BTreeSet<u32>) -> BTreeSet<(Rule, u32)> {
    let mut cov = BTreeSet::new();
    for a in allows {
        let target = if token_lines.contains(&a.line) {
            Some(a.line)
        } else {
            token_lines.range(a.line + 1..).next().copied()
        };
        if let Some(t) = target {
            cov.insert((a.rule, t));
        }
    }
    cov
}

// ----------------------------------------------------------------- rules

const ITER_METHODS: &[&str] = &[
    "iter",
    "iter_mut",
    "into_iter",
    "keys",
    "into_keys",
    "values",
    "values_mut",
    "into_values",
    "drain",
    "retain",
];

const REDUCERS: &[&str] = &["sum", "product", "fold"];

const INT_TYPES: &[&str] = &[
    "u8", "u16", "u32", "u64", "u128", "usize", "i8", "i16", "i32", "i64", "i128", "isize",
];

const ROUNDERS: &[&str] = &["round", "floor", "ceil", "trunc"];

/// Is token `i` part of a `use …;` declaration? (A `use` alone is not a
/// usage site — any real usage is caught where it happens.)
fn in_use_statement(toks: &[Tok], i: usize) -> bool {
    let mut j = i;
    while j > 0 {
        if toks[j - 1].text == ";" {
            break;
        }
        j -= 1;
    }
    toks.get(j).is_some_and(|t| t.text == "use")
}

/// Name bound to an unordered container at token `i` (the `HashMap` /
/// `HashSet` ident): `name: HashMap<…>` field/ascription or
/// `let name = HashMap::new()` binding.
fn binding_name(toks: &[Tok], i: usize) -> Option<String> {
    if i >= 2 && toks[i - 1].text == ":" && toks[i - 2].kind == Kind::Ident {
        return Some(toks[i - 2].text.clone());
    }
    if i >= 2 && toks[i - 1].text == "=" && toks[i - 2].kind == Kind::Ident {
        return Some(toks[i - 2].text.clone());
    }
    None
}

/// Backward scan from a closing `)` / `]` to its opener (same bracket
/// type).
fn matching_open(toks: &[Tok], close: usize) -> Option<usize> {
    let (open_s, close_s) = match toks[close].text.as_str() {
        ")" => ("(", ")"),
        "]" => ("[", "]"),
        _ => return None,
    };
    let mut depth = 0i32;
    let mut j = close as isize;
    while j >= 0 {
        let t = toks[j as usize].text.as_str();
        if t == close_s {
            depth += 1;
        } else if t == open_s {
            depth -= 1;
            if depth == 0 {
                return Some(j as usize);
            }
        }
        j -= 1;
    }
    None
}

/// Start index of the postfix expression a cast at `as_idx` applies to
/// (`as` binds tighter than binary operators, so this walks back over one
/// literal / path / call / index / paren chain).
fn cast_expr_start(toks: &[Tok], as_idx: usize) -> usize {
    let mut j = as_idx as isize - 1;
    loop {
        if j < 0 {
            break;
        }
        let t = &toks[j as usize];
        if t.text == ")" || t.text == "]" {
            match matching_open(toks, j as usize) {
                Some(open) => {
                    j = open as isize - 1;
                    if j >= 0 && matches!(toks[j as usize].kind, Kind::Ident | Kind::Num) {
                        j -= 1;
                    }
                }
                None => break,
            }
        } else if matches!(t.kind, Kind::Ident | Kind::Num) {
            j -= 1;
        } else {
            break;
        }
        if j >= 0 && (toks[j as usize].text == "." || toks[j as usize].text == "::") {
            j -= 1;
            continue;
        }
        break;
    }
    (j + 1) as usize
}

fn num_is_float(s: &str) -> bool {
    if s.starts_with("0x") || s.starts_with("0X") || s.starts_with("0b") || s.starts_with("0o") {
        return false;
    }
    if s.ends_with("f32") || s.ends_with("f64") {
        return true;
    }
    s.contains('.') || s.contains('e') || s.contains('E')
}

/// Does the cast-source span carry textual evidence of a float value?
fn span_has_float_evidence(span: &[Tok]) -> bool {
    span.iter().any(|t| match t.kind {
        Kind::Num => num_is_float(&t.text),
        Kind::Ident => matches!(
            t.text.as_str(),
            "f64" | "f32" | "sqrt" | "powf" | "powi" | "exp" | "ln" | "mean" | "as_secs_f64"
        ),
        Kind::Punct => false,
    })
}

/// Lint one source file. `path` is used for reporting and for the
/// wall-clock allowlist (suffix match).
pub fn lint_source(path: &str, src: &str) -> Vec<Violation> {
    let mut violations: Vec<Violation> = Vec::new();
    // Bench targets get the unordered + wall_clock subset only (see
    // `bench_path`): their float accounting is measurement output, not
    // sim state, so the reduce/cast rules don't apply.
    let bench = bench_path(path);
    let stripped = strip(src);
    let toks = tokenize(&stripped.code);
    let allows = parse_annotations(path, &stripped.comments, &mut violations);
    let token_lines: BTreeSet<u32> = toks.iter().map(|t| t.line).collect();
    let cov = coverage(&allows, &token_lines);
    let covered = |rule: Rule, line: u32| cov.contains(&(rule, line));
    let push = |violations: &mut Vec<Violation>, t: &Tok, rule: Rule, msg: String| {
        violations.push(Violation {
            file: path.to_string(),
            line: t.line,
            col: t.col,
            rule,
            msg,
        });
    };

    // Rule 1: unordered containers. Collect bound names as we go so the
    // later passes can check annotated maps for iteration.
    let mut containers: BTreeMap<String, bool> = BTreeMap::new();
    for (i, t) in toks.iter().enumerate() {
        if t.kind != Kind::Ident || (t.text != "HashMap" && t.text != "HashSet") {
            continue;
        }
        if in_use_statement(&toks, i) {
            continue;
        }
        let allowed = covered(Rule::Unordered, t.line);
        if let Some(name) = binding_name(&toks, i) {
            let e = containers.entry(name).or_insert(false);
            *e = *e || allowed;
        }
        if !allowed {
            push(
                &mut violations,
                t,
                Rule::Unordered,
                format!(
                    "`{}` in a sim-visible module: unordered iteration is \
                     nondeterministic; use BTreeMap / util::detmap::DetMap / a Vec \
                     slab, or annotate `// simlint: allow(unordered, reason = \"…\")`",
                    t.text
                ),
            );
        }
    }

    // Rule 1b + Rule 3: iteration of annotated containers, and float
    // reductions chained onto any unordered-container iterator.
    let mut i = 0;
    while i < toks.len() {
        let t = &toks[i];
        if t.kind == Kind::Ident {
            if let Some(&annotated) = containers.get(&t.text) {
                let dotted = toks.get(i + 1).is_some_and(|d| d.text == ".");
                if dotted {
                    if let Some(m) = toks.get(i + 2) {
                        if m.kind == Kind::Ident && ITER_METHODS.contains(&m.text.as_str()) {
                            if annotated {
                                push(
                                    &mut violations,
                                    m,
                                    Rule::Unordered,
                                    format!(
                                        "container `{}` is annotated allow(unordered) but is \
                                         iterated via `.{}()` — the annotation only covers \
                                         never-iterated use",
                                        t.text,
                                        m.text
                                    ),
                                );
                            }
                            // Scan the rest of the statement for a fold.
                            let mut j = i + 3;
                            let mut steps = 0;
                            while let Some(tj) = toks.get(j) {
                                if tj.text == ";" || steps > 120 {
                                    break;
                                }
                                if tj.text == "." {
                                    if let Some(r) = toks.get(j + 1) {
                                        if r.kind == Kind::Ident
                                            && REDUCERS.contains(&r.text.as_str())
                                            && !bench
                                            && !covered(Rule::FloatReduce, r.line)
                                        {
                                            push(
                                                &mut violations,
                                                r,
                                                Rule::FloatReduce,
                                                format!(
                                                    "`{}.{}()` feeds `.{}()`: reducing an \
                                                     unordered iterator is order-sensitive \
                                                     (float addition is not associative)",
                                                    t.text,
                                                    m.text,
                                                    r.text
                                                ),
                                            );
                                            break;
                                        }
                                    }
                                }
                                j += 1;
                                steps += 1;
                            }
                        }
                    }
                }
            }
            if t.text == "in" {
                let mut j = i + 1;
                if toks.get(j).is_some_and(|x| x.text == "&") {
                    j += 1;
                }
                if toks.get(j).is_some_and(|x| x.text == "mut") {
                    j += 1;
                }
                if let Some(name_tok) = toks.get(j) {
                    if name_tok.kind == Kind::Ident {
                        if let Some(&annotated) = containers.get(&name_tok.text) {
                            let direct = !toks.get(j + 1).is_some_and(|x| x.text == ".");
                            if annotated && direct {
                                push(
                                    &mut violations,
                                    name_tok,
                                    Rule::Unordered,
                                    format!(
                                        "container `{}` is annotated allow(unordered) but is \
                                         iterated by this for-loop — the annotation only \
                                         covers never-iterated use",
                                        name_tok.text
                                    ),
                                );
                            }
                        }
                    }
                }
            }
        }
        i += 1;
    }

    // Rule 2: wall clock / OS entropy / process environment.
    if !wall_clock_exempt(path) {
        for (i, t) in toks.iter().enumerate() {
            if t.kind != Kind::Ident {
                continue;
            }
            let msg = match t.text.as_str() {
                "Instant" | "SystemTime" => Some(format!(
                    "wall-clock type `{}` in a sim-visible module; route timing \
                     through util::wall_clock (allowlisted host boundary)",
                    t.text
                )),
                "thread_rng" | "from_entropy" => Some(format!(
                    "OS entropy `{}` in a sim-visible module; all randomness must \
                     flow through the seeded util::rng::Pcg64",
                    t.text
                )),
                "env" if toks.get(i + 1).is_some_and(|n| n.text == "::") => Some(
                    "process-environment read (`env::…`) in a sim-visible module; \
                     route host access through util::wall_clock"
                        .to_string(),
                ),
                _ => None,
            };
            if let Some(msg) = msg {
                if !covered(Rule::WallClock, t.line) {
                    push(&mut violations, t, Rule::WallClock, msg);
                }
            }
        }
    }

    // Rule 4: truncating float→int casts.
    let mut i = 0;
    while !bench && i + 1 < toks.len() {
        let is_cast = toks[i].kind == Kind::Ident
            && toks[i].text == "as"
            && toks[i + 1].kind == Kind::Ident
            && INT_TYPES.contains(&toks[i + 1].text.as_str());
        if is_cast {
            let explicit_rounding = i >= 3
                && toks[i - 1].text == ")"
                && toks[i - 2].text == "("
                && ROUNDERS.contains(&toks[i - 3].text.as_str());
            if !explicit_rounding {
                let start = cast_expr_start(&toks, i);
                if span_has_float_evidence(&toks[start..i])
                    && !covered(Rule::TruncatingCast, toks[i].line)
                {
                    push(
                        &mut violations,
                        &toks[i],
                        Rule::TruncatingCast,
                        format!(
                            "truncating float→{} `as` cast; make the rounding explicit \
                             (`.floor()` / `.ceil()` / `.round()` / `.trunc()`) or annotate \
                             `// simlint: allow(truncating_cast, reason = \"…\")`",
                            toks[i + 1].text
                        ),
                    );
                }
            }
        }
        i += 1;
    }

    violations.sort_by_key(|v| (v.line, v.col));
    violations
}

// ------------------------------------------------------------ tree walk

fn collect_rs(path: &Path, out: &mut Vec<PathBuf>) -> io::Result<()> {
    let meta = fs::metadata(path)?;
    if meta.is_dir() {
        let mut entries: Vec<PathBuf> = fs::read_dir(path)?
            .map(|e| e.map(|d| d.path()))
            .collect::<io::Result<Vec<_>>>()?;
        entries.sort();
        for p in entries {
            collect_rs(&p, out)?;
        }
    } else if path.extension().is_some_and(|e| e == "rs") {
        out.push(path.to_path_buf());
    }
    Ok(())
}

/// Lint every `.rs` file under `root` (file or directory). Returns the
/// number of files scanned and all findings in path order.
pub fn lint_tree(root: &Path) -> io::Result<(usize, Vec<Violation>)> {
    let mut files = Vec::new();
    collect_rs(root, &mut files)?;
    files.sort();
    let mut out = Vec::new();
    for f in &files {
        let src = fs::read_to_string(f)?;
        let label = f.to_string_lossy().replace('\\', "/");
        out.extend(lint_source(&label, &src));
    }
    Ok((files.len(), out))
}

#[cfg(test)]
mod tests {
    use super::*;

    fn rules(vs: &[Violation]) -> Vec<Rule> {
        vs.iter().map(|v| v.rule).collect()
    }

    // ------------------------------------------------------ fixture suite

    const FIX_UNORDERED: &str = include_str!("../fixtures/unordered.rs");
    const FIX_ALLOW_ITERATED: &str = include_str!("../fixtures/unordered_allow_iterated.rs");
    const FIX_WALL_CLOCK: &str = include_str!("../fixtures/wall_clock.rs");
    const FIX_TRACE_WALL_CLOCK: &str = include_str!("../fixtures/trace_wall_clock.rs");
    const FIX_FLOAT_REDUCE: &str = include_str!("../fixtures/float_reduce.rs");
    const FIX_TRUNCATING_CAST: &str = include_str!("../fixtures/truncating_cast.rs");
    const FIX_FAULTS_THREAD_RNG: &str = include_str!("../fixtures/faults_thread_rng.rs");
    const FIX_BENCH_WALL_CLOCK: &str = include_str!("../fixtures/bench_wall_clock.rs");
    const FIX_CLEAN: &str = include_str!("../fixtures/clean.rs");

    #[test]
    fn fixture_unordered_is_caught() {
        let vs = lint_source("fixtures/unordered.rs", FIX_UNORDERED);
        assert_eq!(rules(&vs), vec![Rule::Unordered, Rule::Unordered], "{vs:?}");
        assert_eq!(vs[0].line, 7, "struct field span: {vs:?}");
        assert_eq!(vs[1].line, 12, "constructor span: {vs:?}");
    }

    #[test]
    fn fixture_annotated_but_iterated_is_caught() {
        let vs = lint_source("fixtures/unordered_allow_iterated.rs", FIX_ALLOW_ITERATED);
        assert_eq!(rules(&vs), vec![Rule::Unordered], "{vs:?}");
        assert_eq!(vs[0].line, 10, "for-loop span: {vs:?}");
        assert!(vs[0].msg.contains("allow(unordered)"), "{}", vs[0].msg);
    }

    #[test]
    fn fixture_wall_clock_is_caught() {
        let vs = lint_source("fixtures/wall_clock.rs", FIX_WALL_CLOCK);
        assert_eq!(
            rules(&vs),
            vec![Rule::WallClock, Rule::WallClock, Rule::WallClock],
            "{vs:?}"
        );
        assert_eq!(vs[0].line, 4, "use-line Instant span: {vs:?}");
        assert_eq!(vs[1].line, 7, "Instant::now span: {vs:?}");
        assert_eq!(vs[2].line, 8, "env::var span: {vs:?}");
    }

    #[test]
    fn fixture_wall_clock_tracer_is_caught() {
        // A tracer stamping records with the host clock instead of sim
        // time is exactly the regression the trace module must never
        // grow; the pass flags every `SystemTime` touch point.
        let vs = lint_source("rust/src/trace/bad.rs", FIX_TRACE_WALL_CLOCK);
        assert_eq!(
            rules(&vs),
            vec![Rule::WallClock, Rule::WallClock, Rule::WallClock],
            "{vs:?}"
        );
        assert_eq!(vs[0].line, 7, "use-line SystemTime span: {vs:?}");
        assert_eq!(vs[1].line, 15, "SystemTime::now span: {vs:?}");
        assert_eq!(vs[2].line, 16, "UNIX_EPOCH span: {vs:?}");
    }

    #[test]
    fn trace_module_is_linted_and_clean() {
        // The satellite guarantee: rust/src/trace/ is inside the linted
        // tree (no allowlist entry covers it) and currently lints clean.
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../src/trace");
        let (files, violations) = lint_tree(&root).unwrap();
        assert!(files >= 2, "trace module should have mod.rs + export.rs, found {files}");
        assert!(
            violations.is_empty(),
            "the trace module must lint clean:\n{}",
            violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
        );
        assert!(!wall_clock_exempt("rust/src/trace/mod.rs"));
    }

    #[test]
    fn fixture_float_reduce_is_caught() {
        let vs = lint_source("fixtures/float_reduce.rs", FIX_FLOAT_REDUCE);
        assert!(rules(&vs).contains(&Rule::FloatReduce), "{vs:?}");
        let fr = vs.iter().find(|v| v.rule == Rule::FloatReduce).unwrap();
        assert_eq!(fr.line, 8, "sum() span: {vs:?}");
    }

    #[test]
    fn fixture_truncating_cast_is_caught() {
        let vs = lint_source("fixtures/truncating_cast.rs", FIX_TRUNCATING_CAST);
        assert_eq!(rules(&vs), vec![Rule::TruncatingCast], "{vs:?}");
        assert_eq!(vs[0].line, 5, "cast span: {vs:?}");
    }

    #[test]
    fn fixture_faults_thread_rng_is_caught() {
        // A fault plane drawing from OS entropy would silently break the
        // dual-run digest contract on every faulty scenario; the pass
        // flags the entropy source and both host-clock touch points.
        let vs = lint_source("rust/src/net/faults_bad.rs", FIX_FAULTS_THREAD_RNG);
        assert_eq!(
            rules(&vs),
            vec![Rule::WallClock, Rule::WallClock, Rule::WallClock],
            "{vs:?}"
        );
        assert_eq!(vs[0].line, 5, "use-line Instant span: {vs:?}");
        assert_eq!(vs[1].line, 8, "thread_rng span: {vs:?}");
        assert!(vs[1].msg.contains("Pcg64"), "{}", vs[1].msg);
        assert_eq!(vs[2].line, 10, "Instant::now span: {vs:?}");
    }

    #[test]
    fn net_faults_module_is_linted_and_clean() {
        // The satellite guarantee for the fault plane: net/faults.rs is
        // inside the linted tree (no allowlist entry covers it) and draws
        // only from its seeded streams — it currently lints clean.
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../src/net/faults.rs");
        let (files, violations) = lint_tree(&root).unwrap();
        assert_eq!(files, 1, "expected exactly net/faults.rs, found {files}");
        assert!(
            violations.is_empty(),
            "net/faults.rs must lint clean:\n{}",
            violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
        );
        assert!(!wall_clock_exempt("rust/src/net/faults.rs"));
    }

    #[test]
    fn fixture_bench_gets_the_unordered_wall_clock_subset() {
        // Under a bench path the env read and the HashMap are caught,
        // but the float reduce and the truncating cast are not.
        let vs = lint_source("rust/benches/perf_bad.rs", FIX_BENCH_WALL_CLOCK);
        assert_eq!(
            rules(&vs),
            vec![Rule::WallClock, Rule::Unordered, Rule::Unordered],
            "{vs:?}"
        );
        assert_eq!(vs[0].line, 8, "env::var span: {vs:?}");
        // The same source inside the sim core trips all four rules.
        let vs = lint_source("rust/src/experiments/perf_bad.rs", FIX_BENCH_WALL_CLOCK);
        let got = rules(&vs);
        assert!(got.contains(&Rule::FloatReduce), "{vs:?}");
        assert!(got.contains(&Rule::TruncatingCast), "{vs:?}");
    }

    #[test]
    fn repo_benches_are_clean() {
        // The bench tree is inside the linted surface (CI runs
        // `simlint rust/src rust/benches`) and currently lints clean.
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../benches");
        let (files, violations) = lint_tree(&root).unwrap();
        assert!(files >= 4, "expected the bench targets, found {files} files");
        assert!(
            violations.is_empty(),
            "the bench tree must lint clean:\n{}",
            violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
        );
        assert!(bench_path("rust/benches/perf_sim.rs"));
        assert!(!bench_path("rust/src/experiments/bench_support.rs"));
    }

    #[test]
    fn fixture_clean_has_no_findings() {
        let vs = lint_source("fixtures/clean.rs", FIX_CLEAN);
        assert!(vs.is_empty(), "clean fixture must lint clean: {vs:?}");
    }

    // --------------------------------------------------- the real source

    #[test]
    fn repo_sim_core_is_clean() {
        let root = Path::new(env!("CARGO_MANIFEST_DIR")).join("../../src");
        let (files, violations) = lint_tree(&root).unwrap();
        assert!(files > 50, "expected the full sim core, found {files} files");
        assert!(
            violations.is_empty(),
            "the sim core must lint clean:\n{}",
            violations.iter().map(|v| v.to_string()).collect::<Vec<_>>().join("\n")
        );
    }

    // ------------------------------------------------------- unit checks

    #[test]
    fn comments_and_strings_are_not_code() {
        let src = "fn f() {\n    // a HashMap in a comment\n    let s = \"HashMap\";\n}\n";
        assert!(lint_source("x.rs", src).is_empty());
    }

    #[test]
    fn use_declarations_are_not_usage_sites() {
        let src = "use std::collections::HashMap;\nfn f() {}\n";
        assert!(lint_source("x.rs", src).is_empty());
        let multi = "use std::collections::{\n    HashMap,\n    HashSet,\n};\nfn f() {}\n";
        assert!(lint_source("x.rs", multi).is_empty());
    }

    #[test]
    fn annotation_suppresses_and_registers_the_container() {
        let src = "struct S {\n    // simlint: allow(unordered, reason = \"lookup only\")\n    \
                   m: HashMap<u64, u64>,\n}\n";
        assert!(lint_source("x.rs", src).is_empty());
    }

    #[test]
    fn annotated_container_methods_that_look_up_are_fine() {
        let src = "// simlint: allow(unordered, reason = \"lookup only\")\n\
                   fn f(m: HashMap<u64, u64>) -> bool {\n    m.contains_key(&1)\n}\n";
        assert!(lint_source("x.rs", src).is_empty());
    }

    #[test]
    fn bad_annotations_are_violations() {
        for bad in [
            "// simlint: allow(unordered)\nfn f() {}\n",
            "// simlint: allow(unordered, reason = \"\")\nfn f() {}\n",
            "// simlint: allow(sloppy, reason = \"x\")\nfn f() {}\n",
            "// simlint: deny(unordered)\nfn f() {}\n",
        ] {
            let vs = lint_source("x.rs", bad);
            assert_eq!(rules(&vs), vec![Rule::BadAnnotation], "{bad:?} -> {vs:?}");
        }
    }

    #[test]
    fn explicit_rounding_exempts_the_cast() {
        let ok = "fn f(x: f64) -> u64 {\n    (x * 1e6).floor() as u64\n}\n";
        assert!(lint_source("x.rs", ok).is_empty());
        let bad = "fn f(x: f64) -> u64 {\n    (x * 1e6) as u64\n}\n";
        assert_eq!(rules(&lint_source("x.rs", bad)), vec![Rule::TruncatingCast]);
    }

    #[test]
    fn integer_casts_do_not_trip_the_cast_rule() {
        let src = "fn f(n: usize) -> u64 {\n    (n >> 3) as u64\n}\n";
        assert!(lint_source("x.rs", src).is_empty());
    }

    #[test]
    fn wall_clock_allowlist_is_suffix_matched() {
        let src = "use std::time::Instant;\nfn f() {}\n";
        assert!(!lint_source("rust/src/sim/engine.rs", src).is_empty());
        assert!(lint_source("rust/src/util/wall_clock.rs", src).is_empty());
        assert!(lint_source("rust/src/cli.rs", src).is_empty());
    }

    #[test]
    fn raw_strings_and_char_literals_do_not_confuse_the_scanner() {
        let src = "fn f() -> usize {\n    let s = r#\"HashMap \"quoted\" text\"#;\n    \
                   let c = '\\'';\n    let l = 'x';\n    \
                   s.len() + (c as usize) + (l as usize)\n}\n";
        assert!(lint_source("x.rs", src).is_empty());
    }
}
