//! CLI driver: `cargo run -p simlint -- rust/src [more paths…]`.
//!
//! Exit codes: 0 clean, 1 violations found, 2 I/O error.

use std::path::PathBuf;

fn main() {
    let mut paths: Vec<PathBuf> = Vec::new();
    for arg in std::env::args().skip(1) {
        match arg.as_str() {
            "-h" | "--help" => {
                println!("usage: simlint <path>…  (lints every .rs file under each path)");
                println!("rules: unordered, wall_clock, float_reduce, truncating_cast");
                println!("see DESIGN.md §Determinism contract for the rule text");
                return;
            }
            _ => paths.push(PathBuf::from(arg)),
        }
    }
    if paths.is_empty() {
        paths.push(PathBuf::from("rust/src"));
    }
    let mut files = 0usize;
    let mut violations = Vec::new();
    for p in &paths {
        match simlint::lint_tree(p) {
            Ok((f, mut v)) => {
                files += f;
                violations.append(&mut v);
            }
            Err(e) => {
                eprintln!("simlint: {}: {e}", p.display());
                std::process::exit(2);
            }
        }
    }
    for v in &violations {
        println!("{v}");
    }
    println!("simlint: {files} file(s) scanned, {} violation(s)", violations.len());
    if !violations.is_empty() {
        std::process::exit(1);
    }
}
