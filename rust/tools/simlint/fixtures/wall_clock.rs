//! Seeded violation: wall-clock and environment reads outside the
//! allowlisted host boundary (rule `wall_clock`).

use std::time::Instant;

pub fn elapsed_secs() -> f64 {
    let start = Instant::now();
    let _quick = std::env::var("QUICK").is_ok();
    start.elapsed().as_secs_f64()
}
