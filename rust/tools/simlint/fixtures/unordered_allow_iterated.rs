//! Seeded violation: a container annotated allow(unordered) that is then
//! iterated — the annotation only covers never-iterated use.

use std::collections::HashMap;

// simlint: allow(unordered, reason = "claimed lookup-only, but see below")
pub fn tally(scores: HashMap<u64, f64>) -> u64 {
    let mut best = 0;
    let mut n = 0;
    for (peer, _) in &scores {
        best = best.max(*peer);
        n += 1;
    }
    best + n
}
