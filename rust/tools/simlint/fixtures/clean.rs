//! Everything here follows the determinism contract: the linter must
//! stay silent on this file.

use std::collections::{BTreeMap, HashMap};

pub struct Clean {
    ordered: BTreeMap<u64, f64>,
    // simlint: allow(unordered, reason = "ticket lookup table, never iterated")
    tickets: HashMap<u64, u64>,
}

impl Clean {
    pub fn new() -> Self {
        Clean {
            ordered: BTreeMap::new(),
            // simlint: allow(unordered, reason = "ticket lookup table, never iterated")
            tickets: HashMap::new(),
        }
    }

    pub fn total(&self) -> f64 {
        self.ordered.values().sum()
    }

    pub fn micros(t: f64) -> u64 {
        (t * 1e6).round() as u64
    }

    pub fn has(&self, ticket: u64) -> bool {
        self.tickets.contains_key(&ticket)
    }
}
