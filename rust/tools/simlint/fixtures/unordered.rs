//! Seeded violation: a bare `HashMap` in sim-visible code (rule
//! `unordered`). The `use` line itself is not a usage site.

use std::collections::HashMap;

pub struct ResultPool {
    by_unit: HashMap<u64, u64>,
}

impl ResultPool {
    pub fn new() -> Self {
        ResultPool { by_unit: HashMap::new() }
    }

    pub fn record(&mut self, unit: u64, peer: u64) {
        self.by_unit.insert(unit, peer);
    }
}
