//! Seeded violation: float reduction over an unordered-container
//! iterator (rule `float_reduce`).

use std::collections::HashMap;

pub fn total(stored: HashMap<u64, f64>) -> f64 {
    // The bare decl above also trips `unordered`; the reduction is the point:
    stored.values().sum()
}
