//! Seeded violation: bare truncating float→int cast in accounting code
//! (rule `truncating_cast`).

pub fn micros(t: f64) -> u64 {
    (t * 1e6) as u64
}
