//! Fixture: a bench target reading the host environment directly and
//! keying results by an unordered map — both must be caught even though
//! benches are exempt from the float-reduce / truncating-cast rules.

use std::collections::HashMap;

fn tier_sizes() -> usize {
    std::env::var("PERF_TIER").map(|v| v.len()).unwrap_or(0)
}

fn main() {
    let rows: HashMap<String, f64> = HashMap::new();
    // Reducing measurement floats and bucketing them is legitimate in a
    // bench driver (skipped there, flagged in sim modules).
    let total: f64 = rows.values().sum();
    let bucket = (total * 10.0) as u64;
    println!("{} {bucket}", tier_sizes());
}
