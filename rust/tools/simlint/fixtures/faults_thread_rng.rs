//! Seeded violation: a fault plane drawing its loss decisions from OS
//! entropy and stamping injections with the host clock instead of the
//! seeded Pcg64 streams + sim time (rule `wall_clock`).

use std::time::Instant;

pub fn probe_lost(loss: f64) -> bool {
    let mut rng = rand::thread_rng();
    let draw: f64 = rng.gen();
    let _stamp = Instant::now();
    draw < loss
}
