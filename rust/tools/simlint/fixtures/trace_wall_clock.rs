//! Seeded violation: a tracer that stamps records with the host clock
//! instead of sim time (rule `wall_clock`). The real tracer
//! (`rust/src/trace/`) stamps `SimTime` + a monotone `seq`, so its
//! stream folds into the determinism digest; a `SystemTime` stamp would
//! make every rerun's trace diverge.

use std::time::SystemTime;

pub struct WallClockTracer {
    pub records: Vec<(f64, &'static str)>,
}

impl WallClockTracer {
    pub fn emit(&mut self, kind: &'static str) {
        let now = SystemTime::now()
            .duration_since(SystemTime::UNIX_EPOCH)
            .unwrap()
            .as_secs_f64();
        self.records.push((now, kind));
    }
}
