//! Build-time stub of the `xla` PJRT bindings.
//!
//! The real backend links `xla_extension` (a multi-GB shared library that
//! is not part of the offline toolchain image). This crate mirrors the
//! subset of the API that `p2pcp::runtime` consumes so the workspace
//! always builds; [`PjRtClient::cpu`] reports a clear error and every
//! consumer (planner service, benches, integration tests, examples) falls
//! back to the native closed-form planner or skips.
//!
//! To use real PJRT execution, replace the `xla = { path = "vendor/xla" }`
//! dependency in `rust/Cargo.toml` with the actual bindings; `p2pcp` calls
//! only the surface defined here, so no other change is needed.

use std::fmt;
use std::path::Path;

/// Error type matching the real bindings' `xla::Error` for Display/From.
#[derive(Debug, Clone)]
pub struct Error(String);

impl Error {
    fn unavailable(what: &str) -> Error {
        Error(format!(
            "{what}: PJRT is unavailable in this build (vendored xla stub; \
             link the real xla bindings to enable the compiled artifact path)"
        ))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.0)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// A host-side literal (tensor value).
#[derive(Debug, Clone)]
pub struct Literal {
    data: Vec<f64>,
    dims: Vec<i64>,
}

impl Literal {
    /// Build a rank-1 f64 literal.
    pub fn vec1(data: &[f64]) -> Literal {
        Literal { data: data.to_vec(), dims: vec![data.len() as i64] }
    }

    /// Reshape to `dims` (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let want: i64 = dims.iter().product();
        if want as usize != self.data.len() {
            return Err(Error(format!(
                "reshape to {dims:?} wants {want} elements, literal has {}",
                self.data.len()
            )));
        }
        Ok(Literal { data: self.data.clone(), dims: dims.to_vec() })
    }

    /// Destructure a tuple literal. Stub literals are never tuples.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        Err(Error::unavailable("Literal::to_tuple"))
    }

    /// Copy out as a typed vector. Stub executions never produce results.
    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }

    pub fn element_count(&self) -> usize {
        self.data.len()
    }

    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

/// Parsed HLO module (text form).
#[derive(Debug, Clone)]
pub struct HloModuleProto(());

impl HloModuleProto {
    /// Parse an HLO text file. The stub accepts the file (so missing-file
    /// errors still surface from the caller) but cannot compile it.
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        match std::fs::read_to_string(path.as_ref()) {
            Ok(_) => Ok(HloModuleProto(())),
            Err(e) => Err(Error(format!("read {}: {e}", path.as_ref().display()))),
        }
    }
}

/// A computation ready for compilation.
#[derive(Debug, Clone)]
pub struct XlaComputation(());

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation(())
    }
}

/// Device-side buffer handle.
#[derive(Debug, Clone)]
pub struct PjRtBuffer(());

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable(());

impl PjRtLoadedExecutable {
    pub fn execute<L: std::borrow::Borrow<Literal>>(
        &self,
        _args: &[L],
    ) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client handle.
#[derive(Debug, Clone)]
pub struct PjRtClient(());

impl PjRtClient {
    /// The stub cannot create a client: report it clearly so callers take
    /// their native fallback.
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn platform_name(&self) -> String {
        "stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_reports_unavailable() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("PJRT is unavailable"));
    }

    #[test]
    fn literal_shapes_check() {
        let l = Literal::vec1(&[1.0, 2.0, 3.0, 4.0, 5.0, 6.0]);
        assert_eq!(l.element_count(), 6);
        assert!(l.reshape(&[2, 3]).is_ok());
        assert!(l.reshape(&[4, 2]).is_err());
    }
}
