"""AOT: lower the L2 planner graph to HLO *text* artifacts for the rust
runtime.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 (the
version the published `xla` 0.1.6 crate links) rejects with
`proto.id() <= INT_MAX`. The text parser reassigns ids and round-trips
cleanly. Lowered with return_tuple=True; the rust side unwraps the tuple.

Usage:  cd python && python -m compile.aot --out-dir ../artifacts

Also writes ``<name>.meta.json`` next to each artifact with the compiled
batch shapes so the rust planner service can size its padding without
parsing HLO.
"""

import argparse
import json
import os

import jax

jax.config.update("jax_enable_x64", True)

from jax._src.lib import xla_client as xc

from . import model
from .kernels.planner import GRID_G


def to_hlo_text(lowered) -> str:
    """stablehlo MLIR -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


ARTIFACTS = {
    "planner": dict(
        fn=model.planner,
        example_args=model.planner_example_args,
        meta=dict(
            batch=model.PLANNER_B,
            window=model.WINDOW_W,
            inputs=["lifetimes[B,W]", "mask[B,W]", "v[B]", "td[B]", "k[B]"],
            outputs=["mu[B]", "lam[B]", "u[B]", "cbar[B]", "twc[B]"],
            dtype="f64",
        ),
    ),
    "usurface": dict(
        fn=model.usurface,
        example_args=model.usurface_example_args,
        meta=dict(
            batch=model.USURFACE_B,
            grid=GRID_G,
            inputs=["mu[B]", "v[B]", "td[B]", "k[B]"],
            outputs=["u[B,G]", "lam[B,G]"],
            dtype="f64",
        ),
    ),
}


def build(out_dir: str, only=None) -> None:
    os.makedirs(out_dir, exist_ok=True)
    for name, spec in ARTIFACTS.items():
        if only and name not in only:
            continue
        lowered = jax.jit(spec["fn"]).lower(*spec["example_args"]())
        text = to_hlo_text(lowered)
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        meta_path = os.path.join(out_dir, f"{name}.meta.json")
        with open(meta_path, "w") as f:
            json.dump(spec["meta"], f, indent=2)
        print(f"wrote {path} ({len(text)} chars) + {meta_path}")


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--only", nargs="*", help="subset of artifact names")
    args = ap.parse_args()
    build(args.out_dir, args.only)


if __name__ == "__main__":
    main()
