"""L2: the planner compute graph (build-time JAX, lowered once to HLO).

Two exported entry points, both batched and shape-static so the rust
coordinator can pad-and-dispatch:

* ``planner(lifetimes [B,W], mask [B,W], v [B], td [B], k [B])``
    -> (mu [B], lam [B], u [B], cbar [B], twc [B])
  Eq. (1) MLE (Pallas), the Lambert-W closed form for lambda* (Pallas W0),
  and the Eqs. (5)-(10) diagnostics at lambda*.

* ``usurface(mu [B], v [B], td [B], k [B])``
    -> (u [B,G], lam [B,G])
  Utilization over a log-spaced rate grid (Pallas), used for grid-argmax
  cross-checks and the utilization-surface figures.

Shapes compiled by aot.py: PLANNER_B=256, WINDOW_W=64, USURFACE_B=32,
G=kernels.planner.GRID_G. All float64 (CPU PJRT target; the W argument
lives near the -1/e branch point).
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels.lambertw import lambertw0
from .kernels.planner import mle_rate, utilization_grid
from .kernels.ref import INV_E

#: Compiled batch shapes (the rust planner service pads to these).
PLANNER_B = 256
WINDOW_W = 64
USURFACE_B = 32


def optimal_lambda(a, v, td):
    """Closed form lambda* = a / (W0(z) + 1) with the Pallas W0 kernel.

    a = k * mu, batched [B] with B a multiple of the kernel BLOCK.
    """
    z = (v * a - td * a - 1.0) / (td * a + 1.0) * INV_E
    w = lambertw0(z)
    wp1 = jnp.maximum(w + 1.0, 1e-12)
    return a / wp1


def utilization_at(lam, a, v, td):
    """Eqs. (5)-(10) diagnostics at a specific rate (plain jnp — XLA fuses
    this into the same computation as the kernels around it)."""
    x = a / jnp.maximum(lam, 1e-300)
    em1 = jnp.expm1(x)
    cbar = 1.0 / jnp.maximum(em1, 1e-300)
    twc = 1.0 / jnp.maximum(a, 1e-300) - cbar / jnp.maximum(lam, 1e-300)
    c_cycle = v + (twc + td) * em1
    u = jnp.clip(1.0 - c_cycle * lam, 0.0, 1.0)
    return u, cbar, twc


def planner(lifetimes, mask, v, td, k):
    """Full adaptive-checkpoint decision for a batch of requests.

    Rows whose window is empty (mask all zero) return mu=0, lam=0, u=0 —
    the rust side treats those as "no estimate yet, keep current interval".
    """
    mu = mle_rate(lifetimes, mask)
    a = k * mu
    lam = optimal_lambda(a, v, td)
    u, cbar, twc = utilization_at(lam, a, v, td)
    empty = mu <= 0.0
    lam = jnp.where(empty, 0.0, lam)
    u = jnp.where(empty, 0.0, u)
    cbar = jnp.where(empty, 0.0, cbar)
    twc = jnp.where(empty, 0.0, twc)
    return mu, lam, u, cbar, twc


def usurface(mu, v, td, k):
    """Utilization surface over the static rate grid for each request."""
    a = k * mu
    return utilization_grid(a, v, td)


def planner_example_args():
    s = jax.ShapeDtypeStruct
    f8 = jnp.float64
    return (
        s((PLANNER_B, WINDOW_W), f8),
        s((PLANNER_B, WINDOW_W), f8),
        s((PLANNER_B,), f8),
        s((PLANNER_B,), f8),
        s((PLANNER_B,), f8),
    )


def usurface_example_args():
    s = jax.ShapeDtypeStruct
    f8 = jnp.float64
    return (
        s((USURFACE_B,), f8),
        s((USURFACE_B,), f8),
        s((USURFACE_B,), f8),
        s((USURFACE_B,), f8),
    )
