"""Pure-jnp reference oracles for the Pallas kernels.

These are the correctness ground truth for everything in this package:

* ``lambertw0_ref``    — principal-branch Lambert W via Halley iteration
                         (pure jnp; cross-checked against scipy in tests).
* ``mle_rate_ref``     — Eq. (1) masked MLE failure-rate estimator.
* ``utilization_ref``  — Eqs. (5)-(10): T'_wc, c-bar, C and U.
* ``optimal_lambda_ref`` — the paper's closed form for the optimal
                         checkpoint rate (Section 3.2.3).

Everything is float64: the planner runs on the CPU PJRT backend where f64
is native, and the Lambert-W argument lives close to the -1/e branch point
where f32 cancellation would cost ~4 digits in (W(z) + 1).
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

#: Number of Halley iterations. The physical z-range for this paper is
#: [-1/e, ~0.4]; 4 iterations already reach ~1 ulp except within 1e-6 of
#: the branch point, 12 covers the tail with margin at trivial cost.
HALLEY_ITERS = 12

INV_E = float(jnp.exp(-1.0))


def _w0_initial_guess(z):
    """Branchless initial guess for W0(z), z >= -1/e.

    Three regimes, blended with selects so the whole thing vectorizes:
      near branch point  : series in p = sqrt(2 (e z + 1))
      moderate |z|       : w = z (1 - z) Pade-flavoured guess around 0
      large z            : asymptotic log(z) - log(log(z))
    """
    z = jnp.asarray(z, jnp.float64)
    # --- near branch point: W0(z) = -1 + p - p^2/3 + 11 p^3 / 72 ...
    p = jnp.sqrt(jnp.maximum(2.0 * (jnp.e * z + 1.0), 0.0))
    w_branch = -1.0 + p * (1.0 + p * (-1.0 / 3.0 + p * (11.0 / 72.0)))
    # --- around zero: W0(z) ~ z (1 - z + 1.5 z^2) (Taylor w = z - z^2 + ...)
    w_zero = z * (1.0 - z * (1.0 - 1.5 * z))
    # --- large z: log(z) - log(log(z)); guard the double log.
    zs = jnp.maximum(z, 2.0)
    lz = jnp.log(zs)
    w_log = lz - jnp.log(lz)
    w = jnp.where(z < -0.25, w_branch, jnp.where(z < 2.0, w_zero, w_log))
    return w


def lambertw0_ref(z):
    """Principal branch W0(z) for z >= -1/e (values below are clamped).

    Fixed-iteration Halley refinement of ``_w0_initial_guess``; branchless,
    so it maps 1:1 onto the Pallas kernel.
    """
    z = jnp.asarray(z, jnp.float64)
    z = jnp.maximum(z, -INV_E)
    w = _w0_initial_guess(z)
    for _ in range(HALLEY_ITERS):
        ew = jnp.exp(w)
        f = w * ew - z
        wp1 = w + 1.0
        # Halley: w -= f / (e^w (w+1) - (w+2) f / (2 (w+1)))
        denom = ew * wp1 - (w + 2.0) * f / (2.0 * wp1)
        # At the branch point wp1 -> 0 and f -> 0; keep the division sane.
        denom = jnp.where(jnp.abs(denom) < 1e-300, 1.0, denom)
        step = f / denom
        w = w - step
    # Exact zero (the only endpoint that is exactly representable; the
    # float64 -1/e is a hair above the true branch point, where W0 is
    # ~ -1 + 1.2e-8 — scipy agrees, so we do NOT pin it to -1).
    w = jnp.where(z == 0.0, 0.0, w)
    return w


def mle_rate_ref(lifetimes, mask):
    """Eq. (1): mu-hat = K / sum_i t_i over the masked lifetime window.

    lifetimes: [..., W] observed peer lifetimes (seconds)
    mask:      [..., W] 1.0 where the observation is valid, 0.0 padding
    Returns the estimated failure rate [...] (0 where the window is empty).
    """
    lifetimes = jnp.asarray(lifetimes, jnp.float64)
    mask = jnp.asarray(mask, jnp.float64)
    count = jnp.sum(mask, axis=-1)
    total = jnp.sum(lifetimes * mask, axis=-1)
    return jnp.where(total > 0.0, count / jnp.maximum(total, 1e-300), 0.0)


def utilization_ref(lam, a, v, td):
    """Eqs. (5)-(10) at checkpoint rate ``lam`` for job failure rate a=k*mu.

    Returns (U, cbar, twc, C):
      cbar = 1 / (e^{a/lam} - 1)          expected fault-free cycles/failure
      twc  = 1/a - cbar/lam               expected wasted work per failure
      C    = v + (twc + td) / cbar        average overhead per cycle
      U    = max(0, 1 - C lam)            average cycle utilization
    """
    lam = jnp.asarray(lam, jnp.float64)
    a = jnp.asarray(a, jnp.float64)
    x = a / jnp.maximum(lam, 1e-300)
    # e^x - 1, stable for small x.
    em1 = jnp.expm1(x)
    cbar = 1.0 / jnp.maximum(em1, 1e-300)
    twc = 1.0 / jnp.maximum(a, 1e-300) - cbar / jnp.maximum(lam, 1e-300)
    c_cycle = v + (twc + td) * em1
    u = 1.0 - c_cycle * lam
    u = jnp.clip(u, 0.0, 1.0)
    return u, cbar, twc, c_cycle


def optimal_lambda_ref(a, v, td):
    """The paper's closed form (Section 3.2.3):

        lambda* = a / ( W0[ (v a - td a - 1) (td a + 1)^-1 e^-1 ] + 1 )

    a = k * mu. Returns lambda* (same shape as the broadcast inputs).
    """
    a = jnp.asarray(a, jnp.float64)
    z = (v * a - td * a - 1.0) / (td * a + 1.0) * INV_E
    w = lambertw0_ref(z)
    wp1 = jnp.maximum(w + 1.0, 1e-12)  # w -> -1 only as v -> 0
    return a / wp1


def planner_ref(lifetimes, mask, v, td, k):
    """End-to-end planner reference: Eq (1) -> closed-form lambda* -> U.

    Returns (mu, lam, u, cbar, twc), each shaped like the batch dims.
    """
    mu = mle_rate_ref(lifetimes, mask)
    a = jnp.asarray(k, jnp.float64) * mu
    lam = optimal_lambda_ref(a, v, td)
    u, cbar, twc, _ = utilization_ref(lam, a, v, td)
    return mu, lam, u, cbar, twc
