"""Pallas kernels: fused planner stages.

Two kernels beyond the Lambert-W core:

* ``mle_rate``         — Eq. (1) masked-MLE failure-rate over a lifetime
                         window, one VMEM tile of [BLOCK_B, W] per step.
* ``utilization_grid`` — Eqs. (5)-(10) evaluated over a log-spaced grid of
                         checkpoint rates relative to the job failure rate;
                         used for grid-argmax cross-validation of the closed
                         form and to regenerate utilization surfaces.

Both are branchless and VPU-shaped (lane dim = 128). interpret=True: see
lambertw.py.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

#: Rows per MLE tile (batch of decision points).
BLOCK_B = 8
#: Grid points for the utilization surface (lane-aligned).
GRID_G = 256
#: Log-spaced multipliers r such that lambda = r * a; spans the useful range
#: from "checkpoint every 100 expected failures" to "100x per failure".
GRID_LO, GRID_HI = 1e-2, 1e2


def _mle_kernel(t_ref, m_ref, mu_ref):
    """mu = sum(mask) / sum(t * mask) per row; 0 for empty windows."""
    t = t_ref[...]
    m = m_ref[...]
    count = jnp.sum(m, axis=-1)
    total = jnp.sum(t * m, axis=-1)
    mu_ref[...] = jnp.where(total > 0.0, count / jnp.maximum(total, 1e-300), 0.0)


@jax.jit
def mle_rate(lifetimes, mask):
    """Eq. (1) over [B, W] windows; B must be a multiple of BLOCK_B."""
    b, w = lifetimes.shape
    assert b % BLOCK_B == 0, f"batch {b} must be a multiple of {BLOCK_B}"
    return pl.pallas_call(
        _mle_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), lifetimes.dtype),
        grid=(b // BLOCK_B,),
        in_specs=[
            pl.BlockSpec((BLOCK_B, w), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_B, w), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((BLOCK_B,), lambda i: (i,)),
        interpret=True,
    )(lifetimes, mask)


def _grid_multipliers(dtype=jnp.float64):
    """The static log-spaced lambda/a multipliers [GRID_G]."""
    return jnp.logspace(
        jnp.log10(GRID_LO), jnp.log10(GRID_HI), GRID_G, dtype=dtype
    )


def _usurface_kernel(a_ref, v_ref, td_ref, r_ref, u_ref, lam_ref):
    """One batch row x full grid: U(lambda_j) for lambda_j = r_j * a_i."""
    a = a_ref[...][:, None]      # [BB, 1]
    v = v_ref[...][:, None]
    td = td_ref[...][:, None]
    r = r_ref[...][None, :]      # [1, G]
    # Floor a to a normal-range value so the a==0 rows (no failures observed
    # yet) stay finite through the intermediate terms; masked out below.
    asafe = jnp.maximum(a, 1e-30)
    lam = r * asafe
    x = asafe / lam              # = 1/r, but keep the general form
    em1 = jnp.expm1(x)
    cbar = 1.0 / jnp.maximum(em1, 1e-300)
    twc = 1.0 / asafe - cbar / lam
    c_cycle = v + (twc + td) * em1
    u = jnp.clip(1.0 - c_cycle * lam, 0.0, 1.0)
    dead = a <= 0.0
    u_ref[...] = jnp.where(dead, 1.0, u)   # no failures -> full utilization
    lam_ref[...] = jnp.where(dead, 0.0, lam)


@jax.jit
def utilization_grid(a, v, td):
    """U over the static rate grid for each row of (a, v, td) — [B] inputs.

    Returns (u [B, G], lam [B, G]).
    """
    (b,) = a.shape
    assert b % BLOCK_B == 0, f"batch {b} must be a multiple of {BLOCK_B}"
    r = _grid_multipliers(a.dtype)
    return pl.pallas_call(
        _usurface_kernel,
        out_shape=(
            jax.ShapeDtypeStruct((b, GRID_G), a.dtype),
            jax.ShapeDtypeStruct((b, GRID_G), a.dtype),
        ),
        grid=(b // BLOCK_B,),
        in_specs=[
            pl.BlockSpec((BLOCK_B,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_B,), lambda i: (i,)),
            pl.BlockSpec((BLOCK_B,), lambda i: (i,)),
            pl.BlockSpec((GRID_G,), lambda i: (0,)),
        ],
        out_specs=(
            pl.BlockSpec((BLOCK_B, GRID_G), lambda i: (i, 0)),
            pl.BlockSpec((BLOCK_B, GRID_G), lambda i: (i, 0)),
        ),
        interpret=True,
    )(a, v, td, r)
