"""Pallas kernel: batched principal-branch Lambert W0.

The planner's hot spot: every adaptive checkpoint decision evaluates

    lambda* = a / (W0(z) + 1),   z = (v a - td a - 1) / (td a + 1) / e

for a batch of (peer x job) decision points. The kernel is branchless
(selects only), uses a fixed Halley iteration count, and tiles the batch
into VMEM-resident lanes via BlockSpec.

TPU mapping (see DESIGN.md section "Hardware adaptation"): this is a pure
VPU (vector unit) workload — transcendental-heavy, no matmul — so the tile
shape is chosen for lane occupancy (multiples of 128) rather than MXU
blocking. interpret=True everywhere: the CPU PJRT plugin cannot execute
Mosaic custom-calls, and numerics are identical by construction.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .ref import HALLEY_ITERS, INV_E

#: Lane-aligned tile for the 1-D batch dimension.
BLOCK = 128


def _lambertw0_kernel(z_ref, w_ref):
    """One VMEM tile: w = W0(max(z, -1/e)) via guess + Halley, branchless."""
    z = jnp.maximum(z_ref[...], -INV_E)

    # Initial guess, three regimes blended with selects (cf. ref._w0_initial_guess).
    p = jnp.sqrt(jnp.maximum(2.0 * (jnp.e * z + 1.0), 0.0))
    w_branch = -1.0 + p * (1.0 + p * (-1.0 / 3.0 + p * (11.0 / 72.0)))
    w_zero = z * (1.0 - z * (1.0 - 1.5 * z))
    zs = jnp.maximum(z, 2.0)
    lz = jnp.log(zs)
    w_log = lz - jnp.log(lz)
    w = jnp.where(z < -0.25, w_branch, jnp.where(z < 2.0, w_zero, w_log))

    # Fixed-count Halley refinement (unrolled — no data-dependent control flow).
    for _ in range(HALLEY_ITERS):
        ew = jnp.exp(w)
        f = w * ew - z
        wp1 = w + 1.0
        denom = ew * wp1 - (w + 2.0) * f / (2.0 * wp1)
        denom = jnp.where(jnp.abs(denom) < 1e-300, 1.0, denom)
        w = w - f / denom

    w = jnp.where(z == 0.0, 0.0, w)
    w_ref[...] = w


@functools.partial(jax.jit, static_argnames=())
def lambertw0(z):
    """Batched W0 over a [B] float64 vector; B must be a multiple of BLOCK."""
    (b,) = z.shape
    assert b % BLOCK == 0, f"batch {b} must be a multiple of {BLOCK}"
    return pl.pallas_call(
        _lambertw0_kernel,
        out_shape=jax.ShapeDtypeStruct((b,), z.dtype),
        grid=(b // BLOCK,),
        in_specs=[pl.BlockSpec((BLOCK,), lambda i: (i,))],
        out_specs=pl.BlockSpec((BLOCK,), lambda i: (i,)),
        interpret=True,
    )(z)


def lambertw0_any(z):
    """W0 for arbitrary batch size: pad to BLOCK, run the kernel, slice."""
    z = jnp.atleast_1d(z)
    (b,) = z.shape
    pad = (-b) % BLOCK
    zp = jnp.pad(z, (0, pad)) if pad else z
    return lambertw0(zp)[:b]
