"""L2 correctness: the planner graph end-to-end vs ref.py, scipy, and the
grid-argmax cross-check of the paper's closed form."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from scipy.special import lambertw as scipy_lambertw

jax.config.update("jax_enable_x64", True)

from compile import model
from compile.kernels.ref import (
    INV_E, optimal_lambda_ref, planner_ref, utilization_ref,
)

B, W = model.PLANNER_B, model.WINDOW_W


def scipy_lambda_star(a, v, td):
    z = (v * a - td * a - 1.0) / (td * a + 1.0) * np.exp(-1.0)
    w = np.real(scipy_lambertw(z, k=0))
    return a / (w + 1.0)


def _mk_inputs(mtbf=7200.0, k=16.0, v=20.0, td=50.0, n_obs=32, seed=0):
    rng = np.random.default_rng(seed)
    lifetimes = np.zeros((B, W))
    mask = np.zeros((B, W))
    lifetimes[:, :n_obs] = rng.exponential(mtbf, size=(B, n_obs))
    mask[:, :n_obs] = 1.0
    j = jnp.asarray
    return (
        j(lifetimes), j(mask),
        jnp.full((B,), v, jnp.float64),
        jnp.full((B,), td, jnp.float64),
        jnp.full((B,), k, jnp.float64),
    )


# ------------------------------------------------------------------ planner


def test_planner_matches_ref():
    args = _mk_inputs()
    got = model.planner(*args)
    want = planner_ref(*args)
    for g, w_ in zip(got, want):
        np.testing.assert_allclose(np.asarray(g), np.asarray(w_),
                                   rtol=1e-10, atol=1e-12)


def test_planner_lambda_matches_scipy():
    args = _mk_inputs()
    mu, lam, _, _, _ = model.planner(*args)
    a = 16.0 * np.asarray(mu)
    want = scipy_lambda_star(a, 20.0, 50.0)
    np.testing.assert_allclose(np.asarray(lam), want, rtol=1e-9)


def test_planner_empty_rows():
    lifetimes, mask, v, td, k = _mk_inputs()
    mask = mask.at[0].set(0.0)
    mu, lam, u, cbar, twc = model.planner(lifetimes, mask, v, td, k)
    assert float(mu[0]) == 0.0
    assert float(lam[0]) == 0.0
    assert float(u[0]) == 0.0
    assert np.isfinite(np.asarray(lam)).all()


def test_planner_interval_sane_for_paper_conditions():
    # MTBF=7200 s, k=16, V=20 s, Td=50 s: group MTBF = 450 s. The optimal
    # interval must checkpoint more often than once per expected failure
    # but less often than the overhead-dominated floor.
    args = _mk_inputs()
    _, lam, u, _, _ = model.planner(*args)
    interval = 1.0 / np.asarray(lam)
    assert (interval < 450.0 * 1.25).all()   # lambda* >= ~a
    assert (interval > 20.0).all()           # not checkpoint-thrashing
    # True U at these conditions is ~0.55; with 32-sample mu-hat noise the
    # per-row values spread to roughly [0.4, 0.7].
    assert (np.asarray(u) > 0.3).all()       # progress is possible


# ------------------------------------------- closed form vs grid argmax


@pytest.mark.parametrize("mtbf,k,v,td", [
    (4000.0, 16.0, 20.0, 50.0),
    (7200.0, 16.0, 20.0, 50.0),
    (14400.0, 16.0, 20.0, 50.0),
    (7200.0, 4.0, 5.0, 10.0),
    (7200.0, 32.0, 40.0, 100.0),
    (450.0, 1.0, 20.0, 50.0),   # single-peer model, Section 3.2.1
])
def test_closed_form_is_grid_argmax(mtbf, k, v, td):
    a = k / mtbf
    lam_star = float(optimal_lambda_ref(jnp.float64(a), v, td))
    # Fine local grid around the closed-form answer.
    lam_grid = jnp.asarray(np.geomspace(lam_star / 50, lam_star * 50, 20001))
    u, _, _, _ = utilization_ref(lam_grid, a, v, td)
    u = np.asarray(u)
    u_star, _, _, _ = utilization_ref(jnp.float64(lam_star), a, v, td)
    assert float(u_star) >= u.max() - 1e-9
    if float(u_star) > 0.0:
        best = float(lam_grid[int(np.argmax(u))])
        assert lam_star == pytest.approx(best, rel=2e-3)


def test_overloaded_regime_u_zero_everywhere():
    # Section 3.2.3: k=64 peers at MTBF=7200 with V=80, Td=200 pushes the
    # overhead past the cycle time for EVERY rate — U(lambda) == 0 on the
    # whole grid and the closed form reports U(lambda*) == 0 ("too many
    # peers"). The coordinator uses this as an admission signal.
    a = 64.0 / 7200.0
    lam_star = float(optimal_lambda_ref(jnp.float64(a), 80.0, 200.0))
    lam_grid = jnp.asarray(np.geomspace(lam_star / 100, lam_star * 100, 4001))
    u, _, _, _ = utilization_ref(lam_grid, a, 80.0, 200.0)
    assert float(np.asarray(u).max()) == 0.0


def test_usurface_argmax_agrees_with_closed_form():
    b = model.USURFACE_B
    mu = jnp.full((b,), 1.0 / 7200.0, jnp.float64)
    v = jnp.full((b,), 20.0, jnp.float64)
    td = jnp.full((b,), 50.0, jnp.float64)
    k = jnp.full((b,), 16.0, jnp.float64)
    u, lam = model.usurface(mu, v, td, k)
    u, lam = np.asarray(u), np.asarray(lam)
    best = lam[0, int(np.argmax(u[0]))]
    want = scipy_lambda_star(16.0 / 7200.0, 20.0, 50.0)
    # Grid is log-spaced with 256 points over 4 decades: ~3.7%/step.
    assert best == pytest.approx(want, rel=0.06)


# --------------------------------------------------- hypothesis: invariants


@settings(max_examples=60, deadline=None)
@given(
    mtbf=st.floats(min_value=600.0, max_value=1e6),
    k=st.floats(min_value=1.0, max_value=256.0),
    v=st.floats(min_value=0.1, max_value=600.0),
    td=st.floats(min_value=0.1, max_value=2000.0),
)
def test_closed_form_hypothesis(mtbf, k, v, td):
    a = k / mtbf
    lam = float(optimal_lambda_ref(jnp.float64(a), v, td))
    assert np.isfinite(lam) and lam > 0
    u_star, _, _, _ = utilization_ref(jnp.float64(lam), a, v, td)
    # Perturbing lambda* in either direction must not improve U.
    for f in (0.9, 1.1):
        u_p, _, _, _ = utilization_ref(jnp.float64(lam * f), a, v, td)
        assert float(u_p) <= float(u_star) + 1e-9


def test_u_zero_signals_too_many_peers():
    # Section 3.2.3: with enough peers, U(lambda*) hits 0 -> job cannot
    # progress. Find the threshold and check monotonicity around it.
    mtbf, v, td = 3600.0, 120.0, 300.0
    us = []
    for k in (1, 2, 4, 8, 16, 32, 64, 128, 256, 512, 1024):
        a = k / mtbf
        lam = float(optimal_lambda_ref(jnp.float64(a), v, td))
        u, _, _, _ = utilization_ref(jnp.float64(lam), a, v, td)
        us.append(float(u))
    assert us[0] > 0.5
    assert us[-1] == 0.0
    assert all(a >= b - 1e-12 for a, b in zip(us, us[1:]))  # non-increasing
