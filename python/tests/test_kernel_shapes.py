"""Hypothesis sweeps over the Pallas kernels' shape/dtype envelope:
arbitrary batch sizes through the padding wrappers, f32 vs f64, and the
BlockSpec tiling invariance (same numbers regardless of how the batch is
tiled)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels.lambertw import BLOCK, lambertw0, lambertw0_any
from compile.kernels.planner import BLOCK_B, GRID_G, mle_rate, utilization_grid
from compile.kernels.ref import lambertw0_ref, mle_rate_ref


# ------------------------------------------------------------ shape sweeps


@settings(max_examples=40, deadline=None)
@given(
    mult=st.integers(min_value=1, max_value=6),
    lo=st.floats(min_value=-0.36, max_value=0.0),
    hi=st.floats(min_value=0.1, max_value=50.0),
)
def test_lambertw_any_block_multiple(mult, lo, hi):
    n = mult * BLOCK
    z = jnp.linspace(lo, hi, n, dtype=jnp.float64)
    got = np.asarray(lambertw0(z))
    want = np.asarray(lambertw0_ref(z))
    np.testing.assert_allclose(got, want, rtol=1e-10, atol=1e-8)


@settings(max_examples=40, deadline=None)
@given(n=st.integers(min_value=1, max_value=3 * BLOCK + 5))
def test_lambertw_any_arbitrary_length(n):
    z = jnp.linspace(0.01, 5.0, n, dtype=jnp.float64)
    got = np.asarray(lambertw0_any(z))
    assert got.shape == (n,)
    want = np.asarray(lambertw0_ref(z))
    np.testing.assert_allclose(got, want, rtol=1e-10)


def test_lambertw_tiling_invariance():
    # The same values computed in one grid step vs many must agree exactly:
    # BlockSpec tiling cannot change the numbers.
    z = jnp.linspace(-0.3, 10.0, 4 * BLOCK, dtype=jnp.float64)
    whole = np.asarray(lambertw0(z))
    parts = np.concatenate(
        [np.asarray(lambertw0(z[i * BLOCK:(i + 1) * BLOCK])) for i in range(4)]
    )
    np.testing.assert_array_equal(whole, parts)


@settings(max_examples=30, deadline=None)
@given(
    rows=st.integers(min_value=1, max_value=4),
    w=st.integers(min_value=1, max_value=96),
)
def test_mle_rate_window_widths(rows, w):
    b = rows * BLOCK_B
    rng = np.random.default_rng(w)
    t = jnp.asarray(rng.exponential(5000.0, size=(b, w)))
    m = jnp.asarray((rng.random((b, w)) < 0.8).astype(np.float64))
    got = np.asarray(mle_rate(t, m))
    want = np.asarray(mle_rate_ref(t, m))
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-15)


def test_usurface_multi_tile_batches():
    for rows in (1, 2, 5):
        b = rows * BLOCK_B
        a = jnp.asarray(np.geomspace(1e-4, 1e-2, b))
        v = jnp.full((b,), 20.0, jnp.float64)
        td = jnp.full((b,), 50.0, jnp.float64)
        u, lam = utilization_grid(a, v, td)
        assert u.shape == (b, GRID_G)
        assert np.isfinite(np.asarray(u)).all()
        # Rows are independent: recompute row 0 alone and compare.
        u1, _ = utilization_grid(a[:BLOCK_B], v[:BLOCK_B], td[:BLOCK_B])
        np.testing.assert_array_equal(np.asarray(u)[:BLOCK_B], np.asarray(u1))


# ------------------------------------------------------------ dtype sweeps


def test_lambertw_f32_tolerances():
    # The kernel is dtype-generic; f32 loses ~4 digits near the branch
    # point but stays within 1e-5 rel on the physical range.
    z64 = jnp.linspace(-0.30, 10.0, 2 * BLOCK, dtype=jnp.float64)
    z32 = z64.astype(jnp.float32)
    got32 = np.asarray(lambertw0(z32))
    assert got32.dtype == np.float32
    want = np.asarray(lambertw0_ref(z64))
    np.testing.assert_allclose(got32, want, rtol=2e-5, atol=2e-6)


def test_mle_f32_matches_f64_loosely():
    rng = np.random.default_rng(3)
    t64 = jnp.asarray(rng.exponential(7200.0, size=(BLOCK_B, 64)))
    m = jnp.ones((BLOCK_B, 64), jnp.float64)
    r64 = np.asarray(mle_rate(t64, m))
    r32 = np.asarray(mle_rate(t64.astype(jnp.float32), m.astype(jnp.float32)))
    assert r32.dtype == np.float32
    np.testing.assert_allclose(r32, r64, rtol=1e-5)


def test_kernel_rejects_misaligned_static_batch():
    with pytest.raises(AssertionError):
        lambertw0(jnp.zeros(BLOCK - 1, jnp.float64))
    with pytest.raises(AssertionError):
        mle_rate(jnp.zeros((BLOCK_B + 1, 8)), jnp.zeros((BLOCK_B + 1, 8)))
