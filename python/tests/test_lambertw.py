"""L1 correctness: Pallas Lambert-W0 kernel vs ref.py vs scipy."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from scipy.special import lambertw as scipy_lambertw

jax.config.update("jax_enable_x64", True)

from compile.kernels.lambertw import BLOCK, lambertw0, lambertw0_any
from compile.kernels.ref import INV_E, lambertw0_ref


def scipy_w0(z):
    return np.real(scipy_lambertw(np.asarray(z, np.float64), k=0))


# ---------------------------------------------------------------- ref oracle


@pytest.mark.parametrize(
    "z",
    [-INV_E, -INV_E + 1e-12, -0.3, -0.1, -1e-6, 0.0, 1e-6, 0.1, 0.5, 1.0,
     np.e, 10.0, 1e3, 1e6],
)
def test_ref_matches_scipy_pointwise(z):
    got = float(lambertw0_ref(jnp.float64(z)))
    want = float(scipy_w0(z))
    # Within ~1e-7 of the branch point W0' diverges like 1/sqrt(z + 1/e);
    # 1e-7 abs is the honest comparison there, 1e-10 rel elsewhere.
    if z < -INV_E + 1e-7:
        assert got == pytest.approx(want, abs=1e-7)
    else:
        assert got == pytest.approx(want, rel=1e-10, abs=1e-12)


def test_ref_identity_w_exp_w():
    z = jnp.logspace(-6, 6, 200, dtype=jnp.float64)
    w = lambertw0_ref(z)
    np.testing.assert_allclose(np.asarray(w * jnp.exp(w)), np.asarray(z),
                               rtol=1e-12)


def test_ref_branch_point():
    # float64 -1/e sits a hair above the true branch point; W0 there is
    # -1 + ~1.2e-8 (scipy agrees).
    assert float(lambertw0_ref(jnp.float64(-INV_E))) == pytest.approx(
        -1.0, abs=1e-7)
    assert float(lambertw0_ref(jnp.float64(0.0))) == 0.0


def test_ref_clamps_below_branch():
    # Arguments below -1/e are clamped to the branch point (rust mirrors this).
    assert float(lambertw0_ref(jnp.float64(-1.0))) == pytest.approx(
        -1.0, abs=1e-7)


def test_ref_monotone_increasing():
    z = jnp.linspace(-INV_E, 5.0, 512, dtype=jnp.float64)
    w = np.asarray(lambertw0_ref(z))
    assert np.all(np.diff(w) >= 0)


# ------------------------------------------------------------- pallas kernel


def test_kernel_matches_ref_grid():
    z = jnp.concatenate([
        jnp.linspace(-INV_E, 0.5, 3 * BLOCK, dtype=jnp.float64),
        jnp.logspace(0, 6, BLOCK, dtype=jnp.float64),
    ])
    got = np.asarray(lambertw0_any(z))
    want = np.asarray(lambertw0_ref(z))
    # atol 1e-8 covers the Halley convergence plateau at the branch point
    # (|W0'| -> inf there); everywhere else rtol 1e-12 binds.
    np.testing.assert_allclose(got, want, rtol=1e-12, atol=1e-8)


def test_kernel_matches_scipy_physical_range():
    # The physical z-range for the paper: z = -beta/e with beta in (0, 1],
    # i.e. z in [-1/e, 0). Dense sweep.
    z = jnp.linspace(-INV_E + 1e-9, -1e-9, 4 * BLOCK, dtype=jnp.float64)
    got = np.asarray(lambertw0_any(z))
    want = scipy_w0(np.asarray(z))
    np.testing.assert_allclose(got, want, rtol=1e-9, atol=1e-11)


def test_kernel_block_alignment():
    with pytest.raises(AssertionError):
        lambertw0(jnp.zeros(BLOCK + 1, jnp.float64))


def test_kernel_any_handles_odd_sizes():
    for n in (1, 7, BLOCK - 1, BLOCK, BLOCK + 1, 3 * BLOCK + 17):
        z = jnp.linspace(0.01, 2.0, n, dtype=jnp.float64)
        got = np.asarray(lambertw0_any(z))
        want = scipy_w0(np.asarray(z))
        np.testing.assert_allclose(got, want, rtol=1e-10)
        assert got.shape == (n,)


@settings(max_examples=200, deadline=None)
@given(st.floats(min_value=-INV_E, max_value=1e6, allow_nan=False))
def test_kernel_hypothesis_sweep(z):
    got = float(lambertw0_any(jnp.float64(z))[0])
    want = float(scipy_w0(z))
    # Near the branch point |W'| diverges; compare through the inverse map
    # w e^w instead of w itself when close.
    if z < -INV_E + 1e-6:
        assert got * np.exp(got) == pytest.approx(max(z, -INV_E), abs=1e-9)
    else:
        assert got == pytest.approx(want, rel=1e-8, abs=1e-10)


@settings(max_examples=100, deadline=None)
@given(
    st.lists(st.floats(min_value=-INV_E, max_value=100.0, allow_nan=False),
             min_size=1, max_size=2 * BLOCK)
)
def test_kernel_hypothesis_batches(zs):
    z = jnp.asarray(zs, jnp.float64)
    got = np.asarray(lambertw0_any(z))
    want = scipy_w0(np.asarray(zs))
    np.testing.assert_allclose(got, want, rtol=1e-8, atol=1e-9)
