"""AOT smoke tests: artifacts lower, contain valid HLO text, and the
lowered planner computes the same numbers as the eager graph."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np

jax.config.update("jax_enable_x64", True)

from compile import aot, model


def test_planner_lowers_to_hlo_text(tmp_path):
    aot.build(str(tmp_path), only=["planner"])
    text = (tmp_path / "planner.hlo.txt").read_text()
    assert "ENTRY" in text and "f64" in text
    meta = json.loads((tmp_path / "planner.meta.json").read_text())
    assert meta["batch"] == model.PLANNER_B
    assert meta["window"] == model.WINDOW_W


def test_usurface_lowers_to_hlo_text(tmp_path):
    aot.build(str(tmp_path), only=["usurface"])
    text = (tmp_path / "usurface.hlo.txt").read_text()
    assert "ENTRY" in text
    meta = json.loads((tmp_path / "usurface.meta.json").read_text())
    assert meta["batch"] == model.USURFACE_B


def test_lowered_planner_numerics_match_eager():
    """Compile the lowered stablehlo back on the local CPU client and compare
    against the eager planner — the exact module text the rust side loads."""
    rng = np.random.default_rng(7)
    B, W = model.PLANNER_B, model.WINDOW_W
    lifetimes = jnp.asarray(rng.exponential(7200.0, size=(B, W)))
    mask = jnp.ones((B, W), jnp.float64)
    v = jnp.full((B,), 20.0, jnp.float64)
    td = jnp.full((B,), 50.0, jnp.float64)
    k = jnp.full((B,), 16.0, jnp.float64)

    eager = model.planner(lifetimes, mask, v, td, k)
    compiled = jax.jit(model.planner).lower(
        *model.planner_example_args()).compile()
    lowered = compiled(lifetimes, mask, v, td, k)
    for e, l in zip(eager, lowered):
        np.testing.assert_allclose(np.asarray(e), np.asarray(l), rtol=1e-12)


def test_repo_artifacts_fresh(request):
    """If artifacts/ exists at the repo root, it must parse as HLO text.
    (Built by `make artifacts`; skipped when absent, e.g. clean checkout.)"""
    root = os.path.join(os.path.dirname(str(request.config.rootpath)), "")
    art = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "..", "artifacts")
    path = os.path.join(art, "planner.hlo.txt")
    if not os.path.exists(path):
        import pytest
        pytest.skip("artifacts/ not built yet")
    text = open(path).read()
    assert "ENTRY" in text
