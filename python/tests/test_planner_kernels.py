"""L1 correctness: MLE + utilization-grid Pallas kernels vs ref.py/numpy."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels.planner import (
    BLOCK_B, GRID_G, GRID_HI, GRID_LO, mle_rate, utilization_grid,
)
from compile.kernels.ref import mle_rate_ref, utilization_ref


# ---------------------------------------------------------------------- MLE


def test_mle_simple():
    t = jnp.full((BLOCK_B, 8), 100.0, jnp.float64)
    m = jnp.ones((BLOCK_B, 8), jnp.float64)
    mu = np.asarray(mle_rate(t, m))
    np.testing.assert_allclose(mu, 1.0 / 100.0, rtol=1e-12)


def test_mle_masked_padding_ignored():
    t = jnp.zeros((BLOCK_B, 16), jnp.float64)
    t = t.at[:, :4].set(jnp.asarray([50.0, 150.0, 100.0, 100.0]))
    # Garbage in the padded region must not leak in.
    t = t.at[:, 4:].set(1e9)
    m = jnp.zeros((BLOCK_B, 16), jnp.float64).at[:, :4].set(1.0)
    mu = np.asarray(mle_rate(t, m))
    np.testing.assert_allclose(mu, 4.0 / 400.0, rtol=1e-12)


def test_mle_empty_window_is_zero():
    t = jnp.ones((BLOCK_B, 8), jnp.float64)
    m = jnp.zeros((BLOCK_B, 8), jnp.float64)
    mu = np.asarray(mle_rate(t, m))
    np.testing.assert_allclose(mu, 0.0)


@settings(max_examples=100, deadline=None)
@given(
    st.lists(
        st.tuples(
            st.floats(min_value=1.0, max_value=1e6),
            st.booleans(),
        ),
        min_size=1, max_size=32,
    )
)
def test_mle_hypothesis(rows):
    w = 32
    t = np.zeros((BLOCK_B, w))
    m = np.zeros((BLOCK_B, w))
    for j, (life, valid) in enumerate(rows):
        t[0, j] = life
        m[0, j] = 1.0 if valid else 0.0
    got = float(mle_rate(jnp.asarray(t), jnp.asarray(m))[0])
    want = float(mle_rate_ref(jnp.asarray(t), jnp.asarray(m))[0])
    assert got == pytest.approx(want, rel=1e-12, abs=1e-15)
    # MLE invariant: mu * sum(t) == count.
    total = (t[0] * m[0]).sum()
    if total > 0:
        assert got * total == pytest.approx(m[0].sum(), rel=1e-9)


# --------------------------------------------------------- utilization grid


def _mk_batch(mtbf=7200.0, k=16.0, v=20.0, td=50.0):
    a = jnp.full((BLOCK_B,), k / mtbf, jnp.float64)
    vv = jnp.full((BLOCK_B,), v, jnp.float64)
    tdd = jnp.full((BLOCK_B,), td, jnp.float64)
    return a, vv, tdd


def test_usurface_matches_ref():
    a, v, td = _mk_batch()
    u, lam = utilization_grid(a, v, td)
    u = np.asarray(u)
    lam = np.asarray(lam)
    assert u.shape == (BLOCK_B, GRID_G)
    u_ref, _, _, _ = utilization_ref(jnp.asarray(lam[0]), a[0], v[0], td[0])
    np.testing.assert_allclose(u[0], np.asarray(u_ref), rtol=1e-12)


def test_usurface_grid_span():
    a, v, td = _mk_batch()
    _, lam = utilization_grid(a, v, td)
    lam = np.asarray(lam)[0]
    a0 = float(a[0])
    assert lam[0] == pytest.approx(GRID_LO * a0, rel=1e-9)
    assert lam[-1] == pytest.approx(GRID_HI * a0, rel=1e-9)
    assert np.all(np.diff(lam) > 0)


def test_usurface_unimodal_interior_peak():
    # For the paper's typical parameters the surface has an interior peak:
    # U drops both for too-small and too-large checkpoint rates.
    a, v, td = _mk_batch()
    u, _ = utilization_grid(a, v, td)
    u = np.asarray(u)[0]
    peak = int(np.argmax(u))
    assert 0 < peak < GRID_G - 1
    assert u[peak] > u[0] and u[peak] > u[-1]
    assert u[peak] > 0.5  # typical conditions are comfortably efficient


def test_usurface_zero_rate_rows():
    # a == 0 rows (no failures observed) must not NaN.
    a, v, td = _mk_batch()
    a = a.at[0].set(0.0)
    u, lam = utilization_grid(a, v, td)
    assert np.isfinite(np.asarray(u)).all()
    assert np.isfinite(np.asarray(lam)).all()
